"""Prometheus text exposition over :class:`~repro.obs.metrics.MetricsRegistry`.

Two render paths share the formatting core:

* :func:`render_registries` walks live registry objects — counters and
  gauges become single samples, histograms become the standard
  cumulative ``_bucket{le=...}`` / ``_sum`` / ``_count`` triple (only
  occupied buckets plus the mandatory ``+Inf`` are emitted; cumulative
  counts stay exact because empty buckets add nothing).  Derived gauges
  are evaluated at render time, like :meth:`MetricsRegistry.snapshot`.
* :func:`render_snapshot` re-renders a *flat* snapshot dict (the
  ``name{k=v}`` → value/summary shape benches and flight bundles store)
  — histogram summaries become Prometheus *summary* quantile rows since
  the bucket counts are gone by then.

Names are sanitized to the Prometheus grammar (``.`` and any other
illegal character → ``_``); label values are escaped per the text
format.  No external client library — the text format is ~20 lines of
string assembly, and the container must not grow dependencies.
"""

from __future__ import annotations

import math
import re

from .metrics import Counter, Gauge, Histogram, MetricsRegistry

__all__ = ["render_registries", "render_snapshot", "sanitize_name"]

_NAME_OK = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*$")
_NAME_BAD = re.compile(r"[^a-zA-Z0-9_:]")
_FLAT_KEY = re.compile(r"^(?P<name>[^{]+)(?:\{(?P<labels>.*)\})?$")


def sanitize_name(name: str) -> str:
    """Map a registry metric name onto the Prometheus grammar."""
    out = _NAME_BAD.sub("_", name)
    if not _NAME_OK.match(out):
        out = "_" + out
    return out


def _esc_label(v: object) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _labels(labels: dict, extra: tuple[tuple[str, str], ...] = ()) -> str:
    pairs = [(sanitize_name(str(k)), _esc_label(v)) for k, v in sorted(labels.items())]
    pairs += list(extra)
    if not pairs:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in pairs) + "}"


def _num(v: float) -> str:
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, int):
        return str(v)
    f = float(v)
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    if math.isnan(f):
        return "NaN"
    return repr(f)


def _le(edge: float) -> str:
    if math.isinf(edge):
        return "+Inf"
    return f"{edge:.6g}"


class _Family:
    """One exposition family: TYPE header + accumulated sample lines."""

    def __init__(self, name: str, kind: str):
        self.name = name
        self.kind = kind
        self.lines: list[str] = []


def _families_from_registry(reg: MetricsRegistry, fams: dict[str, _Family]) -> None:
    for name, labels, m in reg.items():
        pname = sanitize_name(name)
        if isinstance(m, Counter):
            fam = fams.setdefault(pname, _Family(pname, "counter"))
            fam.lines.append(f"{pname}{_labels(labels)} {_num(m.value)}")
        elif isinstance(m, Gauge):
            fam = fams.setdefault(pname, _Family(pname, "gauge"))
            fam.lines.append(f"{pname}{_labels(labels)} {_num(m.value)}")
        elif isinstance(m, Histogram):
            fam = fams.setdefault(pname, _Family(pname, "histogram"))
            for edge, cum in m.cumulative_buckets():
                fam.lines.append(
                    f"{pname}_bucket{_labels(labels, (('le', _le(edge)),))} {cum}"
                )
            fam.lines.append(
                f"{pname}_bucket{_labels(labels, (('le', '+Inf'),))} {m.count}"
            )
            fam.lines.append(f"{pname}_sum{_labels(labels)} {_num(m.sum)}")
            fam.lines.append(f"{pname}_count{_labels(labels)} {m.count}")
    for name, labels, v in reg.derived_items():
        pname = sanitize_name(name)
        fam = fams.setdefault(pname, _Family(pname, "gauge"))
        fam.lines.append(f"{pname}{_labels(labels)} {_num(v)}")


def _emit(fams: dict[str, _Family]) -> str:
    out: list[str] = []
    for name in sorted(fams):
        fam = fams[name]
        out.append(f"# TYPE {name} {fam.kind}")
        out.extend(fam.lines)
    return "\n".join(out) + "\n" if out else ""


def render_registries(*registries: MetricsRegistry) -> str:
    """Prometheus text exposition of one or more live registries (the
    engine registry plus :func:`~repro.obs.metrics.process_registry`).
    Read-only and lock-free on the serving path: it reads GIL-published
    metric objects the same way the snapshot path does."""
    fams: dict[str, _Family] = {}
    for reg in registries:
        _families_from_registry(reg, fams)
    return _emit(fams)


def _parse_flat_key(key: str) -> tuple[str, dict]:
    m = _FLAT_KEY.match(key)
    if m is None:
        return key, {}
    name = m.group("name")
    raw = m.group("labels")
    labels: dict = {}
    if raw:
        for part in raw.split(","):
            k, _, v = part.partition("=")
            labels[k] = v
    return name, labels


def render_snapshot(snapshot: dict) -> str:
    """Re-render a flat ``MetricsRegistry.snapshot()`` dict (e.g. the
    ``metrics`` section of a flight bundle) as Prometheus text.
    Histogram summaries become summary-type quantile rows."""
    fams: dict[str, _Family] = {}
    for key in sorted(snapshot):
        value = snapshot[key]
        name, labels = _parse_flat_key(key)
        pname = sanitize_name(name)
        if isinstance(value, dict):  # histogram summary row
            fam = fams.setdefault(pname, _Family(pname, "summary"))
            for q in ("p50", "p90", "p99"):
                if q in value:
                    qv = str(float(q[1:]) / 100.0)
                    fam.lines.append(
                        f"{pname}{_labels(labels, (('quantile', qv),))} "
                        f"{_num(value[q])}"
                    )
            fam.lines.append(f"{pname}_sum{_labels(labels)} {_num(value.get('sum', 0.0))}")
            fam.lines.append(f"{pname}_count{_labels(labels)} {value.get('count', 0)}")
        elif isinstance(value, (int, float)):
            fam = fams.setdefault(pname, _Family(pname, "gauge"))
            fam.lines.append(f"{pname}{_labels(labels)} {_num(value)}")
    return _emit(fams)
