"""Counters, gauges, and log-bucketed histograms for serving telemetry.

Metrics are the always-on half of the obs subsystem (spans are the
optional recording half): every engine owns a :class:`MetricsRegistry`
and the legacy ``EngineStats`` surface is rebuilt as *views* over it.

Histograms are **log-bucketed**: values land in geometric buckets of
ratio ``10^(1/20)`` (20 per decade, ≈12% width), so p50/p90/p99 come
from bucket counts alone — no samples stored, O(1) memory per metric,
O(1) ``observe``.  Signed mode mirrors the buckets around a zero bucket
so the planner's pred/obs *log-residuals* (which are signed) get the
same treatment.

Metric identity is ``(name, labels)`` where labels is a sorted tuple of
``(key, value)`` pairs — the engines key phase timings by
``(phase, backend, shard)`` per the paper's filter/verify split.
Derived gauges are registered as callables evaluated at snapshot time
(hit ratios, MVCC lag, throttle duty cycle), so the hot path never pays
for them.
"""

from __future__ import annotations

import math
import threading

import numpy as np

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "process_registry",
]

#: Geometric bucket layout: ratio 10^(1/BUCKETS_PER_DECADE) between
#: bucket edges.  20/decade bounds the relative quantile error at
#: ~±6% (half a bucket width) — comfortably inside the 15% tolerance
#: the percentile tests assert against numpy.
BUCKETS_PER_DECADE = 20
#: Magnitudes below LO collapse into the zero bucket; above HI into the
#: overflow bucket.  [1e-8, 1e4) covers nanosecond spans to hour-long
#: phases, and (signed) planner log-residuals of every plausible size.
LO = 1e-8
HI = 1e4
_N_MAG = int(round(BUCKETS_PER_DECADE * math.log10(HI / LO)))  # per sign
_LOG_LO = math.log10(LO)


def _mag_bucket(mag: float) -> int:
    """Bucket index of a positive magnitude in [0, _N_MAG]."""
    if mag < LO:
        return -1  # caller folds into the zero bucket
    if mag >= HI:
        return _N_MAG  # overflow bucket (open-ended)
    return int((math.log10(mag) - _LOG_LO) * BUCKETS_PER_DECADE)


def _mag_value(idx: int) -> float:
    """Geometric midpoint of magnitude bucket ``idx``."""
    if idx >= _N_MAG:
        return HI
    return 10.0 ** (_LOG_LO + (idx + 0.5) / BUCKETS_PER_DECADE)


class Counter:
    """Monotone counter (GIL-atomic ``inc`` — single Python add).

    Increments are usually integers (events); float increments are
    allowed for monotone accumulated quantities (``compile.time_s``).
    """

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int | float = 1) -> None:
        self.value += n


class Gauge:
    """Last-value (or max-tracking) instantaneous metric."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def set_max(self, v: float) -> None:
        if v > self.value:
            self.value = v


class Histogram:
    """Log-bucketed distribution: quantiles without stored samples.

    ``signed=True`` adds a mirrored negative range (and a zero bucket)
    for values like log-residuals; plain timing histograms clamp
    negatives to the zero bucket.
    """

    __slots__ = ("signed", "counts", "count", "sum", "min", "max")

    def __init__(self, signed: bool = False):
        self.signed = signed
        # layout: [neg _N_MAG..0] ++ [zero] ++ [pos 0.._N_MAG]
        n = (2 * (_N_MAG + 1) + 1) if signed else (_N_MAG + 2)
        self.counts = np.zeros(n, np.int64)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def _index(self, v: float) -> int:
        if self.signed:
            zero = _N_MAG + 1
            if v > 0:
                b = _mag_bucket(v)
                return zero if b < 0 else zero + 1 + b
            if v < 0:
                b = _mag_bucket(-v)
                return zero if b < 0 else zero - 1 - b
            return zero
        b = _mag_bucket(v) if v > 0 else -1
        return 0 if b < 0 else 1 + b

    def _value(self, idx: int) -> float:
        if self.signed:
            zero = _N_MAG + 1
            if idx == zero:
                return 0.0
            if idx > zero:
                return _mag_value(idx - zero - 1)
            return -_mag_value(zero - 1 - idx)
        return 0.0 if idx == 0 else _mag_value(idx - 1)

    def observe(self, v: float) -> None:
        self.counts[self._index(v)] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def merge(self, other: "Histogram") -> None:
        """Accumulate another histogram (same signedness) in place."""
        if other.signed != self.signed:
            raise ValueError("cannot merge signed with unsigned histogram")
        self.counts += other.counts
        self.count += other.count
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)

    def reset(self) -> None:
        self.counts[:] = 0
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def percentile(self, q: float) -> float:
        """Bucket-midpoint quantile estimate, clamped to observed
        min/max (exact at the tails, ≲½-bucket error inside)."""
        if self.count == 0:
            return 0.0
        target = q / 100.0 * self.count
        acc = 0
        for idx, c in enumerate(self.counts):
            acc += int(c)
            if acc >= target and c:
                return min(max(self._value(idx), self.min), self.max)
        return self.max

    def abs_percentile(self, q: float) -> float:
        """Quantile of |value| — the planner drift gate's median
        |log-residual| (folds the signed mirror onto magnitudes)."""
        if self.count == 0:
            return 0.0
        if not self.signed:
            return abs(self.percentile(q))
        zero = _N_MAG + 1
        folded = np.zeros(_N_MAG + 2, np.int64)
        folded[0] = self.counts[zero]
        for b in range(_N_MAG + 1):
            folded[1 + b] = self.counts[zero + 1 + b] + self.counts[zero - 1 - b]
        target = q / 100.0 * self.count
        acc = 0
        cap = max(abs(self.min), abs(self.max))
        for idx, c in enumerate(folded):
            acc += int(c)
            if acc >= target and c:
                return min((0.0 if idx == 0 else _mag_value(idx - 1)), cap)
        return cap

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def _upper_edge(self, idx: int) -> float:
        """Upper bucket boundary (the Prometheus ``le`` value) of slot
        ``idx``; ``inf`` for the positive overflow bucket."""
        if self.signed:
            zero = _N_MAG + 1
            if idx == zero:
                return LO  # zero bucket covers (-LO, LO)
            if idx > zero:
                b = idx - zero - 1  # positive magnitude bucket
                if b >= _N_MAG:
                    return math.inf
                return 10.0 ** (_LOG_LO + (b + 1) / BUCKETS_PER_DECADE)
            b = zero - 1 - idx  # negative magnitude bucket
            # covers (-10^(lo+(b+1)/BPD), -10^(lo+b/BPD)]
            return -(10.0 ** (_LOG_LO + b / BUCKETS_PER_DECADE))
        if idx == 0:
            return LO
        b = idx - 1
        if b >= _N_MAG:
            return math.inf
        return 10.0 ** (_LOG_LO + (b + 1) / BUCKETS_PER_DECADE)

    def cumulative_buckets(self) -> list[tuple[float, int]]:
        """Non-empty buckets as Prometheus-style cumulative
        ``(upper_edge, count_le)`` pairs, ascending.  Only occupied
        buckets are emitted (the renderer appends ``+Inf`` = count), so
        exposition size tracks the observed spread, not the layout."""
        out: list[tuple[float, int]] = []
        acc = 0
        for idx, c in enumerate(self.counts):
            if c:
                acc += int(c)
                out.append((self._upper_edge(idx), acc))
        return out

    def summary(self) -> dict:
        """Flat snapshot row: count/sum/mean and the headline quantiles."""
        if self.count == 0:
            return {"count": 0, "sum": 0.0}
        return {
            "count": self.count,
            "sum": self.sum,
            "mean": self.mean,
            "min": self.min,
            "max": self.max,
            "p50": self.percentile(50.0),
            "p90": self.percentile(90.0),
            "p99": self.percentile(99.0),
        }


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


def _fmt_key(name: str, key: tuple) -> str:
    if not key:
        return name
    return name + "{" + ",".join(f"{k}={v}" for k, v in key) + "}"


class MetricsRegistry:
    """Get-or-create store of metrics keyed by ``(name, labels)``.

    Lookup is a dict hit (no lock on the hot path — creation is locked,
    reads ride the GIL like the rest of the MVCC read path); engines
    additionally cache handles for their per-query metrics so steady
    state is attribute access + int add.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._store: dict[tuple, object] = {}
        self._derived: dict[tuple, object] = {}

    def _get(self, cls, name: str, labels: dict, **kw):
        key = (name, _label_key(labels))
        m = self._store.get(key)
        if m is None:
            with self._lock:
                m = self._store.get(key)
                if m is None:
                    m = cls(**kw)
                    self._store[key] = m
        if not isinstance(m, cls):
            raise TypeError(f"metric {key} already registered as {type(m).__name__}")
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, signed: bool = False, **labels) -> Histogram:
        return self._get(Histogram, name, labels, signed=signed)

    def derived(self, name: str, fn, **labels) -> None:
        """Register a gauge computed at snapshot time; ``fn`` returning
        ``None`` omits the row (signal not available yet)."""
        with self._lock:
            self._derived[(name, _label_key(labels))] = fn

    # ---- read side --------------------------------------------------------
    def items(self) -> list[tuple[str, dict, object]]:
        """Every live metric as ``(name, labels, metric)`` — the object
        view the Prometheus renderer needs (bucket counts, not just the
        quantile summary :meth:`snapshot` flattens to)."""
        with self._lock:
            items = sorted(self._store.items())
        return [(k[0], dict(k[1]), m) for k, m in items]

    def derived_items(self) -> list[tuple[str, dict, float]]:
        """Derived gauges evaluated now, as ``(name, labels, value)``;
        rows whose callable fails or returns ``None`` are omitted."""
        with self._lock:
            derived = sorted(self._derived.items())
        out = []
        for (name, key), fn in derived:
            try:
                v = fn()
            except Exception:
                v = None
            if v is not None:
                out.append((name, dict(key), float(v)))
        return out

    def find(self, name: str) -> list[tuple[dict, object]]:
        """All metrics registered under ``name`` as (labels, metric)."""
        with self._lock:
            items = list(self._store.items())
        return [(dict(k[1]), m) for k, m in items if k[0] == name]

    def snapshot(self) -> dict:
        """Flat ``{"name{k=v}": value-or-summary}`` dict for benches and
        the export CLI.  Derived gauges are evaluated here, never on the
        serving path."""
        with self._lock:
            items = sorted(self._store.items())
            derived = sorted(self._derived.items())
        out: dict = {}
        for key, m in items:
            k = _fmt_key(*key)
            if isinstance(m, Counter):
                out[k] = m.value
            elif isinstance(m, Gauge):
                out[k] = m.value
            else:
                out[k] = m.summary()
        for key, fn in derived:
            try:
                v = fn()
            except Exception:
                v = None
            if v is not None:
                out[_fmt_key(*key)] = v
        return out


#: Process-wide registry for metrics that are not per-engine: jit
#: compile counts, span-ring intern overflows, flight-recorder activity.
#: Engines merge it into their own exposition (``/metrics``, flight
#: bundles) so process facts travel with every engine's scrape.
_PROCESS = MetricsRegistry()


def process_registry() -> MetricsRegistry:
    return _PROCESS
