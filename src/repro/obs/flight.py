"""Flight recorder: a crash / SLO-breach black box for serving engines.

When something goes wrong in production — a writer exception mid-update,
a reader exception under traffic, a watchdog hang, a sustained SLO
breach — the record of *what the engine was doing* is usually gone by
the time anyone looks.  The flight recorder freezes it: one call to
:meth:`FlightRecorder.dump` writes a versioned postmortem bundle
(``flight/<stamp>_<reason>.json``) containing

* the last-N span records across **all** thread rings (plus exact
  dropped / intern-overflow counts, so "the trace is incomplete" is a
  stated fact, not a surprise),
* the full metrics snapshot (engine registry merged with the
  process-wide registry: compile counts, flight activity),
* the engine config, snapshot version + facility fingerprint, dataset
  cardinalities, shard partition summary,
* the active planner profile id/epoch,
* the exception type/message/traceback when one triggered the dump,
* the sentinel's rule states when a sentinel is attached.

Arming: ``RkNNConfig(flight_recorder=True)`` attaches a recorder at
engine construction; or use the recorder as a context manager around a
risky region (it attaches to the engine for the block and dumps on any
exception leaving the block).  Dumps are rate-limited (a crash loop
writes one bundle per ``min_interval_s``, the rest are counted in
``flight.suppressed``) and everything read is lock-free — rings via
seqlock, metrics via GIL-published objects — so dumping never perturbs
concurrent serving beyond the serialization cost itself.

Bundles replay in the CLI: ``python -m repro.obs --postmortem <bundle>``.
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
import traceback
from datetime import datetime, timezone

from .export import spans as _decode_spans
from .metrics import process_registry
from .trace import get_tracer

__all__ = ["FlightRecorder", "SCHEMA"]

SCHEMA = "rknn-flight/1"


def _jsonable(obj):
    """Best-effort JSON coercion for config/metrics payloads."""
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple, set, frozenset)):
        return [_jsonable(v) for v in obj]
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {
            f.name: _jsonable(getattr(obj, f.name)) for f in dataclasses.fields(obj)
        }
    item = getattr(obj, "item", None)  # numpy scalars
    if callable(item):
        try:
            return _jsonable(item())
        except Exception:
            pass
    return str(obj)


class FlightRecorder:
    """Black-box bundle writer bound to one engine.

    Thread-safe: any reader/writer/watchdog thread may call
    :meth:`dump`; the internal lock only serializes bundle writes (never
    the serving path, which merely *holds a reference* to the recorder).
    """

    def __init__(
        self,
        engine,
        dir: str = "flight",
        *,
        max_spans: int = 512,
        min_interval_s: float = 5.0,
    ):
        self.engine = engine
        self.dir = dir
        self.max_spans = int(max_spans)
        self.min_interval_s = float(min_interval_s)
        self._lock = threading.Lock()
        self._last_dump = -float("inf")
        self._seq = 0
        reg = process_registry()
        self._bundles = reg.counter("flight.bundles")
        self._suppressed = reg.counter("flight.suppressed")
        self.last_path: str | None = None

    # ---- arming -----------------------------------------------------------
    def __enter__(self) -> "FlightRecorder":
        """Arm for a block: the engine carries this recorder while the
        block runs, and any exception leaving the block dumps."""
        self._prev = getattr(self.engine, "flight", None)
        self.engine.flight = self
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.engine.flight = self._prev
        if exc is not None:
            self.dump("exception:block", exc=exc)

    # ---- capture ----------------------------------------------------------
    def record_exception(self, where: str, exc: BaseException) -> str | None:
        """Dump with the exception attached; returns the bundle path (or
        ``None`` when rate-limited).  Never raises — a broken recorder
        must not mask the original failure."""
        try:
            return self.dump(f"exception:{where}", exc=exc)
        except Exception:
            return None

    def dump(self, reason: str, *, exc: BaseException | None = None) -> str | None:
        now = time.monotonic()
        with self._lock:
            if now - self._last_dump < self.min_interval_s:
                self._suppressed.inc()
                return None
            self._last_dump = now
            self._seq += 1
            seq = self._seq
        bundle = self._bundle(reason, exc)
        os.makedirs(self.dir, exist_ok=True)
        stamp = datetime.now(timezone.utc).strftime("%Y%m%dT%H%M%S")
        safe = "".join(c if (c.isalnum() or c in "-_") else "-" for c in reason)
        path = os.path.join(self.dir, f"{stamp}_{seq:03d}_{safe}.json")
        with open(path, "w") as f:
            json.dump(bundle, f, indent=1, default=str)
            f.write("\n")
        self._bundles.inc()
        self.last_path = path
        return path

    def _bundle(self, reason: str, exc: BaseException | None) -> dict:
        engine = self.engine
        tracer = get_tracer()
        recs = sorted(_decode_spans(tracer), key=lambda r: r["t1"])[-self.max_spans:]
        snap = getattr(engine, "_snap", None)
        shard_state = getattr(snap, "shard_state", None)
        try:
            from repro.planner.profiles import get_active_profile, profile_epoch

            prof = get_active_profile()
            planner = dict(
                profile=getattr(prof, "version", None),
                hardware=getattr(prof, "hardware", None),
                epoch=profile_epoch(),
            )
        except Exception:
            planner = None
        metrics = {}
        m = getattr(engine, "metrics", None)
        if m is not None:
            metrics.update(m.snapshot())
        metrics.update(process_registry().snapshot())
        sentinel = getattr(engine, "_sentinel", None)
        return dict(
            schema=SCHEMA,
            reason=reason,
            wall_time=datetime.now(timezone.utc).isoformat(),
            engine=dict(
                **{"class": type(engine).__name__},
                config=_jsonable(getattr(engine, "config", None)),
                version=getattr(snap, "version", None),
                fingerprint=snap.fingerprint() if snap is not None else None,
                n_facilities=(
                    len(snap.facilities) if snap is not None else None
                ),
                n_users=len(snap.users) if snap is not None else None,
                shards=(
                    shard_state.summary() if shard_state is not None else None
                ),
                persist=_jsonable(getattr(engine, "persist_info", None)),
            ),
            planner=planner,
            metrics=_jsonable(metrics),
            spans=_jsonable(recs),
            spans_dropped=tracer.dropped,
            intern_overflows=tracer.intern_overflows,
            exception=(
                None
                if exc is None
                else dict(
                    type=type(exc).__name__,
                    message=str(exc),
                    traceback=traceback.format_exception(
                        type(exc), exc, exc.__traceback__
                    ),
                )
            ),
            sentinel=(sentinel.state() if sentinel is not None else None),
        )
