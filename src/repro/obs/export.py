"""Serialize a span recording: Chrome ``trace_event`` JSON + summaries.

``chrome://tracing`` / Perfetto load the output of
:func:`chrome_trace` directly: each span becomes a complete event
(``ph: "X"``) with microsecond ``ts``/``dur``, the ring's thread id as
``tid``, and the span attrs as ``args`` — so a sharded ``query_batch``
renders as a ``batch`` bar with nested ``filter``/``verify`` bars and
per-shard children under them.

:func:`summarize` is the text twin for terminals/CI logs, and
:func:`metrics_snapshot` just re-exports the registry's flat dict so
benches import one module.
"""

from __future__ import annotations

import json

from .metrics import Histogram, MetricsRegistry
from .trace import Tracer, get_tracer

__all__ = [
    "spans",
    "chrome_trace",
    "write_chrome_trace",
    "summarize",
    "metrics_snapshot",
]


def spans(tracer: Tracer | None = None) -> list[dict]:
    """Stable decoded span records, globally time-ordered."""
    tracer = tracer or get_tracer()
    return sorted(tracer.records(), key=lambda r: r["t0"])


def chrome_trace(tracer: Tracer | None = None) -> dict:
    """The recording as a Chrome ``trace_event`` JSON object."""
    tracer = tracer or get_tracer()
    recs = spans(tracer)
    t_base = recs[0]["t0"] if recs else 0.0
    events: list[dict] = []
    seen_tids: set[int] = set()
    for r in recs:
        if r["tid"] not in seen_tids:
            seen_tids.add(r["tid"])
            events.append(
                {
                    "ph": "M",
                    "name": "thread_name",
                    "pid": 0,
                    "tid": r["tid"],
                    "args": {"name": f"thread-{len(seen_tids)}"},
                }
            )
        events.append(
            {
                "ph": "X",
                "name": r["name"],
                "pid": 0,
                "tid": r["tid"],
                "ts": (r["t0"] - t_base) * 1e6,
                "dur": (r["t1"] - r["t0"]) * 1e6,
                "args": {**r["attrs"], "seq": r["seq"], "parent": r["parent"]},
            }
        )
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"dropped_spans": tracer.dropped},
    }


def write_chrome_trace(path: str, tracer: Tracer | None = None) -> dict:
    """Write :func:`chrome_trace` to ``path``; returns the object."""
    obj = chrome_trace(tracer)
    with open(path, "w") as fh:
        json.dump(obj, fh)
    return obj


def metrics_snapshot(registry: MetricsRegistry) -> dict:
    """Flat bench-friendly dict of one registry (see
    :meth:`MetricsRegistry.snapshot`)."""
    return registry.snapshot()


def summarize(recs: list[dict]) -> dict:
    """Per-(name, backend) latency digest of decoded span records.

    Works on live :func:`spans` output *or* a reloaded Chrome trace's
    ``traceEvents`` (the CLI path) — pass records through
    :func:`_from_chrome` for the latter.
    """
    groups: dict[tuple, Histogram] = {}
    for r in recs:
        key = (r["name"], r["attrs"].get("backend", "-"))
        h = groups.get(key)
        if h is None:
            h = groups[key] = Histogram()
        h.observe(r["t1"] - r["t0"])
    out = {}
    for (name, backend), h in sorted(groups.items()):
        label = name if backend == "-" else f"{name}[{backend}]"
        out[label] = h.summary()
    return out


def _from_chrome(obj: dict) -> list[dict]:
    """Decode a Chrome trace JSON back into summarizable records."""
    recs = []
    for ev in obj.get("traceEvents", []):
        if ev.get("ph") != "X":
            continue
        args = dict(ev.get("args", {}))
        seq = args.pop("seq", -1)
        parent = args.pop("parent", -1)
        t0 = ev["ts"] / 1e6
        recs.append(
            {
                "tid": ev.get("tid", 0),
                "seq": seq,
                "parent": parent,
                "name": ev["name"],
                "attrs": args,
                "t0": t0,
                "t1": t0 + ev.get("dur", 0.0) / 1e6,
                "depth": 0,
            }
        )
    return recs
