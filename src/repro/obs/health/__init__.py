"""Live introspection over a serving engine — see :mod:`.server`."""

from .server import ObsServer, serve

__all__ = ["ObsServer", "serve"]
