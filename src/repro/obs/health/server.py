"""Live introspection HTTP endpoint for a serving engine.

``engine.serve_obs(port=0)`` boots a stdlib
:class:`~http.server.ThreadingHTTPServer` on a daemon thread and serves:

=============  ============================================================
``/metrics``   Prometheus text exposition of the engine's registry merged
               with the process registry (compile counts, intern
               overflows, flight activity); histograms as cumulative
               ``_bucket{le=...}`` rows.
``/spans``     The most recent decoded span records across all thread
               rings as JSON (``?n=`` caps the count, default 256) plus
               exact dropped / intern-overflow counts.
``/explain``   The engine's recent ``auto`` plans (``engine.explain()``).
``/snapshot``  The served MVCC version: version number, facility
               fingerprint, dataset cardinalities, rect, shard partition
               summary, and per-category device-memory bytes.
``/healthz``   SLO evaluation via the engine's sentinel — 200 + ``ok``
               while healthy, 503 with the tripped rule states otherwise.
=============  ============================================================

Read-only and **lock-free by construction**: every handler reads the
same seqlock span rings, GIL-published metric objects, and atomically
swapped snapshot reference the serving path uses — no handler acquires
a lock a query path could ever wait on, so scraping cannot perturb
tail latency beyond its own CPU cost.  Each request resolves
``engine._snap`` exactly once, like a query does, so a concurrent
update stream yields monotone versions and never a torn mix.
"""

from __future__ import annotations

import dataclasses
import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlparse

from ..export import spans as _decode_spans
from ..metrics import process_registry
from ..promtext import render_registries
from ..trace import get_tracer

__all__ = ["ObsServer", "serve"]


def _jsonable(obj):
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple, set, frozenset)):
        return [_jsonable(v) for v in obj]
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {
            f.name: _jsonable(getattr(obj, f.name)) for f in dataclasses.fields(obj)
        }
    item = getattr(obj, "item", None)  # numpy scalars
    if callable(item):
        try:
            return _jsonable(item())
        except Exception:
            pass
    return str(obj)


class _Handler(BaseHTTPRequestHandler):
    server_version = "rknn-obs/1"
    protocol_version = "HTTP/1.1"

    # set per server class in ObsServer
    engine = None

    def log_message(self, fmt, *args):  # quiet: scrapers are chatty
        pass

    def _send(self, code: int, body: bytes, ctype: str) -> None:
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _send_json(self, payload, code: int = 200) -> None:
        body = json.dumps(_jsonable(payload), indent=1).encode()
        self._send(code, body, "application/json")

    def do_GET(self) -> None:  # noqa: N802 — http.server API
        url = urlparse(self.path)
        route = url.path.rstrip("/") or "/"
        try:
            if route == "/metrics":
                text = render_registries(self.engine.metrics, process_registry())
                self._send(200, text.encode(), "text/plain; version=0.0.4")
            elif route == "/spans":
                qs = parse_qs(url.query)
                n = int(qs.get("n", ["256"])[0])
                tracer = get_tracer()
                recs = _decode_spans(tracer)[-max(n, 0):]
                self._send_json(
                    dict(
                        spans=recs,
                        dropped=tracer.dropped,
                        intern_overflows=tracer.intern_overflows,
                        tracing_enabled=tracer.enabled,
                    )
                )
            elif route == "/explain":
                self._send_json(dict(plans=self.engine.explain()))
            elif route == "/snapshot":
                self._send_json(self._snapshot_payload())
            elif route == "/healthz":
                sentinel = self.engine.sentinel
                ok = sentinel.observe()
                self._send_json(
                    dict(ok=ok, rules=sentinel.state()),
                    code=200 if ok else 503,
                )
            elif route == "/":
                self._send_json(
                    dict(routes=["/metrics", "/spans", "/explain",
                                 "/snapshot", "/healthz"])
                )
            else:
                self._send_json(dict(error=f"no route {route}"), code=404)
        except BrokenPipeError:
            pass
        except Exception as e:  # a broken scrape must not kill the server
            try:
                self._send_json(
                    dict(error=f"{type(e).__name__}: {e}"), code=500
                )
            except Exception:
                pass

    def _snapshot_payload(self) -> dict:
        engine = self.engine
        snap = engine._snap  # resolved ONCE, like a query entry point
        rect = snap.rect
        shard_state = snap.shard_state
        return dict(
            version=snap.version,
            fingerprint=snap.fingerprint(),
            n_facilities=len(snap.facilities),
            n_users=len(snap.users),
            rect=dict(
                xmin=rect.xmin, ymin=rect.ymin, xmax=rect.xmax, ymax=rect.ymax
            ),
            mesh_n=snap.mesh_n,
            shards=(shard_state.summary() if shard_state is not None else None),
            device_bytes=engine._device_bytes_cached(snap),
            scene_cache_len=(
                len(snap.scene_cache) if snap.scene_cache is not None else 0
            ),
            persist=getattr(engine, "persist_info", None),
        )


class ObsServer:
    """One engine's introspection endpoint (daemon threads; ephemeral
    port by default so tests and co-located engines never collide)."""

    def __init__(self, engine, port: int = 0, host: str = "127.0.0.1"):
        handler = type("_BoundHandler", (_Handler,), {"engine": engine})
        self.engine = engine
        self._httpd = ThreadingHTTPServer((host, port), handler)
        self._httpd.daemon_threads = True
        self.host = host
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever,
            name=f"rknn-obs-{self.port}",
            daemon=True,
        )
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=2.0)

    def __enter__(self) -> "ObsServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def serve(engine, port: int = 0, host: str = "127.0.0.1") -> ObsServer:
    return ObsServer(engine, port=port, host=host)
