"""``python -m repro.obs`` — dump/summarize a span recording.

    python -m repro.obs trace.json              # per-span latency digest
    python -m repro.obs trace.json --slowest 10 # widest spans
"""

from __future__ import annotations

import argparse
import json
import sys

from .export import _from_chrome, summarize


def _fmt_s(v: float) -> str:
    if v >= 1.0:
        return f"{v:8.3f}s "
    if v >= 1e-3:
        return f"{v * 1e3:8.3f}ms"
    return f"{v * 1e6:8.1f}µs"


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Summarize a Chrome-trace recording exported by repro.obs",
    )
    ap.add_argument("trace", help="trace JSON written by write_chrome_trace()")
    ap.add_argument(
        "--slowest", type=int, default=0, metavar="N",
        help="also list the N widest spans",
    )
    args = ap.parse_args(argv)

    with open(args.trace) as fh:
        obj = json.load(fh)
    recs = _from_chrome(obj)
    dropped = obj.get("otherData", {}).get("dropped_spans", 0)
    print(f"{len(recs)} spans ({dropped} dropped at record time)")
    print(f"{'span':<28}{'count':>7}{'total':>11}{'p50':>11}{'p99':>11}")
    for label, s in summarize(recs).items():
        print(
            f"{label:<28}{s['count']:>7}"
            f"{_fmt_s(s['sum']):>11}{_fmt_s(s['p50']):>11}{_fmt_s(s['p99']):>11}"
        )
    if args.slowest:
        recs.sort(key=lambda r: r["t0"] - r["t1"])
        print(f"\nslowest {args.slowest}:")
        for r in recs[: args.slowest]:
            attrs = ",".join(f"{k}={v}" for k, v in sorted(r["attrs"].items()))
            print(f"  {_fmt_s(r['t1'] - r['t0'])}  {r['name']}  {attrs}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
