"""``python -m repro.obs`` — inspect recordings, snapshots, postmortems.

    python -m repro.obs trace.json              # per-span latency digest
    python -m repro.obs trace.json --slowest 10 # widest spans
    python -m repro.obs --prom metrics.json     # snapshot → Prometheus text
    python -m repro.obs --postmortem flight/<bundle>.json   # flight digest
"""

from __future__ import annotations

import argparse
import json
import sys

from .export import _from_chrome, summarize
from .promtext import render_snapshot


def _fmt_s(v: float) -> str:
    if v >= 1.0:
        return f"{v:8.3f}s "
    if v >= 1e-3:
        return f"{v * 1e3:8.3f}ms"
    return f"{v * 1e6:8.1f}µs"


def _digest_trace(path: str, slowest: int) -> int:
    with open(path) as fh:
        obj = json.load(fh)
    recs = _from_chrome(obj)
    dropped = obj.get("otherData", {}).get("dropped_spans", 0)
    print(f"{len(recs)} spans ({dropped} dropped at record time)")
    print(f"{'span':<28}{'count':>7}{'total':>11}{'p50':>11}{'p99':>11}")
    for label, s in summarize(recs).items():
        print(
            f"{label:<28}{s['count']:>7}"
            f"{_fmt_s(s['sum']):>11}{_fmt_s(s['p50']):>11}{_fmt_s(s['p99']):>11}"
        )
    if slowest:
        recs.sort(key=lambda r: r["t0"] - r["t1"])
        print(f"\nslowest {slowest}:")
        for r in recs[:slowest]:
            attrs = ",".join(f"{k}={v}" for k, v in sorted(r["attrs"].items()))
            print(f"  {_fmt_s(r['t1'] - r['t0'])}  {r['name']}  {attrs}")
    return 0


def _render_prom(path: str) -> int:
    """A metrics snapshot (flat dict, or any JSON object with a
    ``metrics`` section — e.g. a flight bundle) as Prometheus text."""
    with open(path) as fh:
        obj = json.load(fh)
    snap = obj.get("metrics", obj) if isinstance(obj, dict) else obj
    sys.stdout.write(render_snapshot(snap))
    return 0


def _digest_postmortem(path: str, slowest: int) -> int:
    """Human-readable flight-bundle digest: what / when / why, the
    breached sentinel rules, and the slowest recorded spans."""
    with open(path) as fh:
        b = json.load(fh)
    schema = b.get("schema", "?")
    eng = b.get("engine") or {}
    planner = b.get("planner") or {}
    print(f"flight bundle {schema} — reason: {b.get('reason')}")
    print(f"  at      {b.get('wall_time')}")
    print(
        f"  engine  {eng.get('class')} v{eng.get('version')} "
        f"fp={eng.get('fingerprint')} "
        f"F={eng.get('n_facilities')} U={eng.get('n_users')}"
    )
    shards = eng.get("shards")
    if shards:
        print(
            f"  shards  {shards.get('n_shards')} shards, "
            f"{shards.get('n_users')} users, "
            f"imbalance {shards.get('imbalance'):.3f}"
        )
    if planner:
        print(
            f"  planner profile={planner.get('profile')} "
            f"epoch={planner.get('epoch')}"
        )
    exc = b.get("exception")
    if exc:
        print(f"  exception {exc.get('type')}: {exc.get('message')}")
        tb = exc.get("traceback") or []
        if tb:
            print("    " + tb[-1].strip().replace("\n", "\n    "))
    sent = b.get("sentinel")
    if sent:
        tripped = {k: v for k, v in sent.items() if v.get("tripped")}
        if tripped:
            print(f"  breached rules ({len(tripped)}):")
            for name, st in sorted(tripped.items()):
                print(
                    f"    {name}: last={st.get('last')} "
                    f"baseline={st.get('baseline')} ({st.get('last_breach')})"
                )
        else:
            print("  sentinel: no rules tripped")
    recs = b.get("spans") or []
    print(
        f"  {len(recs)} spans captured "
        f"({b.get('spans_dropped', 0)} dropped, "
        f"{b.get('intern_overflows', 0)} intern overflows)"
    )
    n = slowest or 5
    widest = sorted(recs, key=lambda r: r["t0"] - r["t1"])[:n]
    if widest:
        print(f"  slowest {len(widest)}:")
        for r in widest:
            attrs = ",".join(f"{k}={v}" for k, v in sorted(r["attrs"].items()))
            print(f"    {_fmt_s(r['t1'] - r['t0'])}  {r['name']}  {attrs}")
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Summarize a Chrome-trace recording, render a metrics "
        "snapshot as Prometheus text, or digest a flight bundle",
    )
    ap.add_argument(
        "trace", nargs="?", default=None,
        help="trace JSON written by write_chrome_trace()",
    )
    ap.add_argument(
        "--slowest", type=int, default=0, metavar="N",
        help="also list the N widest spans",
    )
    ap.add_argument(
        "--prom", default=None, metavar="SNAPSHOT",
        help="render a metrics-snapshot JSON (or a flight bundle's metrics "
        "section) as Prometheus text and exit",
    )
    ap.add_argument(
        "--postmortem", default=None, metavar="BUNDLE",
        help="print a human-readable digest of a flight-recorder bundle",
    )
    args = ap.parse_args(argv)

    if args.prom:
        return _render_prom(args.prom)
    if args.postmortem:
        return _digest_postmortem(args.postmortem, args.slowest)
    if args.trace is None:
        ap.error("a trace file, --prom, or --postmortem is required")
    return _digest_trace(args.trace, args.slowest)


if __name__ == "__main__":
    sys.exit(main())
