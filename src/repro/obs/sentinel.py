"""Online regression sentinel: EWMA+MAD drift tripwires with hysteresis.

The paper's performance claim is regime-dependent (ray-cast filtering
wins exactly where R-tree pruning degrades), so a serving engine can
regress *silently* when the workload drifts — the planner keeps routing,
latency creeps, cache hit ratios sag, and nothing fails.  The sentinel
watches a small set of metric families and trips when one drifts beyond
its own learned baseline (or past an absolute SLO bound):

* **Baseline**: per rule, an exponentially-weighted mean of the observed
  value plus an EWMA of absolute deviation (a robust MAD-style scale).
  A sample *breaches* when it lands more than ``k_mad`` deviations on
  the rule's bad side of the baseline — or past the rule's absolute
  ``limit`` when one is declared.
* **Hysteresis**: a rule trips only after ``trip_after`` consecutive
  breaching samples and clears only after ``clear_after`` consecutive
  healthy ones, so single outliers (a GC pause, one cold compile) never
  flap ``/healthz``.  While tripped the baseline is **frozen** — a
  sustained regression must recover, not merely persist long enough to
  be learned as the new normal.
* **Surfacing**: every breaching sample bumps
  ``sentinel.breach{rule=...}``; trips flip the per-rule
  ``sentinel.tripped`` gauge (and therefore ``/healthz``), and a trip
  triggers the engine's flight recorder when one is armed — the
  postmortem bundle then carries the exact rule states.

Default rules for an engine (:func:`engine_rules`) cover the families
the ISSUE names: per-backend query-phase latency (discovered lazily as
the engine creates its per-``(phase, backend)`` histograms), scene/batch
cache hit ratios, planner ``|ln(obs/pred)|`` medians, MVCC version lag,
and shard imbalance.  Everything the sentinel reads is lock-free (the
same GIL-published metric objects the snapshot path reads).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable

from .metrics import MetricsRegistry, process_registry

__all__ = ["Rule", "Sentinel", "engine_rules"]

#: Planner drift bound shared with the scenario-sweep CI gate: median
#: |ln(observed/predicted)| per assigned backend must stay under this.
DRIFT_LIMIT = 1.5


@dataclasses.dataclass
class Rule:
    """One watched signal.

    ``value`` is pulled at every :meth:`Sentinel.observe`; ``None``
    means "no signal yet" and is skipped entirely (no baseline update,
    no breach).  ``direction`` names the *bad* side: ``"high"`` rules
    breach above baseline (latency, lag, imbalance), ``"low"`` rules
    breach below it (hit ratios).  ``limit`` is an optional absolute SLO
    bound breached regardless of the learned baseline.
    """

    name: str
    value: Callable[[], float | None]
    direction: str = "high"  # "high" | "low"
    limit: float | None = None
    k_mad: float = 6.0
    trip_after: int = 3
    clear_after: int = 2
    warmup: int = 8
    alpha: float = 0.2
    rel_floor: float = 0.05  # deviation floor as a fraction of |baseline|


class _RuleState:
    __slots__ = (
        "rule", "mean", "dev", "n", "breach_streak", "ok_streak",
        "tripped", "trips", "last", "last_breach",
    )

    def __init__(self, rule: Rule):
        self.rule = rule
        self.mean = 0.0
        self.dev = 0.0
        self.n = 0
        self.breach_streak = 0
        self.ok_streak = 0
        self.tripped = False
        self.trips = 0
        self.last: float | None = None
        self.last_breach: str | None = None


class Sentinel:
    """Evaluates a rule set against live metrics; owns ``/healthz``.

    ``observe()`` is cheap (a handful of metric reads per rule) and
    lock-free on everything it touches; call it from a poller thread
    (:meth:`start`) or let the health server call it per ``/healthz``
    request.  ``discover`` — when given — runs before each observation
    and may register additional rules (used to pick up per-backend
    histograms the engine creates lazily).
    """

    def __init__(
        self,
        rules: list[Rule] | None = None,
        *,
        registry: MetricsRegistry | None = None,
        on_trip: Callable[["_RuleState"], None] | None = None,
        discover: Callable[["Sentinel"], None] | None = None,
    ):
        self._states: dict[str, _RuleState] = {}
        self._reg = registry if registry is not None else process_registry()
        self._on_trip = on_trip
        self._discover = discover
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()
        for r in rules or []:
            self.add_rule(r)

    def add_rule(self, rule: Rule) -> None:
        """Idempotent by name — re-adding an existing rule is a no-op,
        which is what lazy discovery needs."""
        if rule.name not in self._states:
            self._states[rule.name] = _RuleState(rule)

    @property
    def rules(self) -> list[str]:
        return list(self._states)

    # ---- evaluation -------------------------------------------------------
    def _eval(self, st: _RuleState, v: float) -> str | None:
        """Breach reason for sample ``v`` under ``st``'s baseline, or
        ``None`` when healthy."""
        rule = st.rule
        if rule.limit is not None:
            if rule.direction == "high" and v > rule.limit:
                return f"limit({v:.4g}>{rule.limit:.4g})"
            if rule.direction == "low" and v < rule.limit:
                return f"limit({v:.4g}<{rule.limit:.4g})"
        if st.n < rule.warmup:
            return None
        floor = rule.rel_floor * abs(st.mean)
        thr = rule.k_mad * max(st.dev, floor, 1e-12)
        if rule.direction == "high" and v > st.mean + thr:
            return f"drift({v:.4g}>{st.mean:.4g}+{thr:.4g})"
        if rule.direction == "low" and v < st.mean - thr:
            return f"drift({v:.4g}<{st.mean:.4g}-{thr:.4g})"
        return None

    def observe(self) -> bool:
        """Pull every rule once; returns the post-observation health."""
        if self._discover is not None:
            try:
                self._discover(self)
            except Exception:
                pass
        for st in list(self._states.values()):
            rule = st.rule
            try:
                v = rule.value()
            except Exception:
                v = None
            if v is None:
                continue
            v = float(v)
            st.last = v
            breach = self._eval(st, v)
            if breach is not None:
                st.last_breach = breach
                st.breach_streak += 1
                st.ok_streak = 0
                self._reg.counter("sentinel.breach", rule=rule.name).inc()
                if not st.tripped and st.breach_streak >= rule.trip_after:
                    st.tripped = True
                    st.trips += 1
                    self._reg.gauge("sentinel.tripped", rule=rule.name).set(1.0)
                    if self._on_trip is not None:
                        try:
                            self._on_trip(st)
                        except Exception:
                            pass
            else:
                st.ok_streak += 1
                st.breach_streak = 0
                if st.tripped and st.ok_streak >= rule.clear_after:
                    st.tripped = False
                    self._reg.gauge("sentinel.tripped", rule=rule.name).set(0.0)
                if not st.tripped:
                    # frozen while tripped: a sustained regression must
                    # recover, not get adopted as the new baseline
                    a = rule.alpha if st.n else 1.0
                    st.mean += a * (v - st.mean)
                    st.dev += a * (abs(v - st.mean) - st.dev)
                    st.n += 1
        return self.healthy

    @property
    def healthy(self) -> bool:
        return not any(st.tripped for st in self._states.values())

    def state(self) -> dict:
        """JSON-able per-rule digest for ``/healthz`` and flight bundles."""
        return {
            name: dict(
                tripped=st.tripped,
                trips=st.trips,
                last=st.last,
                baseline=(st.mean if st.n else None),
                dev=(st.dev if st.n else None),
                samples=st.n,
                breach_streak=st.breach_streak,
                last_breach=st.last_breach,
            )
            for name, st in sorted(self._states.items())
        }

    # ---- background poller ------------------------------------------------
    def start(self, interval_s: float = 1.0) -> "Sentinel":
        if self._thread is not None:
            return self
        self._stop.clear()

        def loop() -> None:
            while not self._stop.wait(interval_s):
                self.observe()

        self._thread = threading.Thread(
            target=loop, name="rknn-sentinel", daemon=True
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        t, self._thread = self._thread, None
        if t is not None:
            t.join(timeout=2.0)


# ---------------------------------------------------------------------------
# default rule families for an engine
# ---------------------------------------------------------------------------
def engine_rules(engine) -> tuple[list[Rule], Callable[[Sentinel], None]]:
    """The ISSUE's default watch list for one engine: static rules over
    the always-present families plus a discovery hook that adds a
    latency rule per ``(phase, backend)`` histogram as the engine
    creates them lazily."""
    m = engine.metrics

    def derived_value(name: str) -> Callable[[], float | None]:
        def value() -> float | None:
            for n, _labels, v in m.derived_items():
                if n == name:
                    return v
            return None

        return value

    def gauge_value(name: str) -> Callable[[], float | None]:
        def value() -> float | None:
            found = m.find(name)
            return found[0][1].value if found else None

        return value

    def drift_value() -> float | None:
        worst = None
        for _labels, h in m.find("planner.residual"):
            if h.count >= 8:
                med = h.abs_percentile(50.0)
                worst = med if worst is None else max(worst, med)
        return worst

    rules = [
        Rule("scene_cache.hit_ratio", derived_value("scene_cache.hit_ratio"),
             direction="low"),
        Rule("batch_cache.hit_ratio", derived_value("batch_cache.hit_ratio"),
             direction="low"),
        Rule("mvcc.version_lag", gauge_value("mvcc.version_lag"),
             direction="high"),
        Rule("shard.imbalance", gauge_value("shard.imbalance"),
             direction="high"),
        Rule("planner.drift", drift_value, direction="high",
             limit=DRIFT_LIMIT),
    ]

    def discover(sentinel: Sentinel) -> None:
        for labels, h in m.find("phase_s"):
            phase = labels.get("phase", "-")
            backend = labels.get("backend", "-")
            for q in (50.0, 99.0):
                hist = h

                def value(hist=hist, q=q) -> float | None:
                    return hist.percentile(q) if hist.count >= 8 else None

                sentinel.add_rule(
                    Rule(f"p{int(q)}.{phase}.{backend}", value,
                         direction="high")
                )

    return rules, discover
