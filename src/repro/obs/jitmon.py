"""Retrace/compile accounting for ``jax.jit`` entry points.

An accidental recompile (a pad-bucket miss storm, a shape leak through
the planner's grouping) shows up today as a mystery latency spike.
:func:`track_jit` wraps a jitted callable and, after every call,
compares the callable's compilation-cache size against the last
observation — growth means this call traced+compiled, so the wrapper
charges the call's wall time to ``compile.time_s{fn=...}`` and bumps
``compile.count{fn=...}`` in the process-wide registry.

Cost when nothing compiles: one ``perf_counter`` pair plus a
``_cache_size()`` lookup per call — noise next to a kernel dispatch.
The cache-size probe is versioned across jax releases; when absent the
wrapper degrades to counting nothing (never to breaking the call).

The attribution is per *wrapped callable*, which matches how the engine
jits: each mesh step / reference kernel is its own ``jax.jit`` object,
so cache growth on the wrapper's function is exactly "this entry point
retraced".
"""

from __future__ import annotations

import functools
import time

from .metrics import process_registry

__all__ = ["track_jit"]


def _cache_size(fn) -> int | None:
    probe = getattr(fn, "_cache_size", None)
    if probe is None:
        return None
    try:
        return int(probe())
    except Exception:
        return None


def track_jit(fn, name: str):
    """Wrap a jitted callable; compiles surface as ``compile.count{fn}``
    and ``compile.time_s{fn}`` in :func:`process_registry`.

    Returns ``fn`` unchanged when the compilation-cache probe is
    unavailable (non-jit callable, or a jax without ``_cache_size``).
    """
    if _cache_size(fn) is None:
        return fn
    reg = process_registry()
    count = reg.counter("compile.count", fn=name)
    time_s = reg.counter("compile.time_s", fn=name)
    state = {"n": _cache_size(fn) or 0}

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        n = _cache_size(fn)
        if n is not None and n > state["n"]:
            count.inc(n - state["n"])
            time_s.inc(time.perf_counter() - t0)
            state["n"] = n
        return out

    wrapper.lower = getattr(fn, "lower", None)
    wrapper.__wrapped_jit__ = fn
    return wrapper
