"""Atomic, sharded, async-capable checkpointing (no orbax; from scratch).

Layout:  ``<dir>/step_<N>/{manifest.json, <leaf-id>.npy...}``
* leaves are path-addressed (stable across param-tree refactors that keep
  names), saved as host numpy;
* writes go to ``step_<N>.tmp`` then atomically ``rename`` — a crash mid-
  write never corrupts the latest checkpoint (the restart driver picks the
  newest *complete* step);
* ``AsyncCheckpointer`` overlaps serialization with the next train steps
  (one in-flight snapshot, joined before the next save — the standard
  double-buffer policy);
* ``restore`` optionally ``device_put``s straight into a sharding tree so
  a 512-way FSDP state never materialises unsharded on one host.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading

import numpy as np

import jax

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "AsyncCheckpointer"]

_SAFE = re.compile(r"[^A-Za-z0-9_.\-]")


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out[_SAFE.sub("_", key)] = leaf
    return out


def save_checkpoint(directory: str, step: int, tree, *, keep: int = 3, extra: dict | None = None) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:012d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    for key, leaf in flat.items():
        arr = np.asarray(leaf)
        fn = f"{key.replace('/', '__')}.npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"][key] = {
            "file": fn,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    steps = sorted(_all_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:012d}"), ignore_errors=True)


def _all_steps(directory: str) -> list[int]:
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "manifest.json")):
            out.append(int(m.group(1)))
    return out


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = _all_steps(directory)
    return max(steps) if steps else None


def restore_checkpoint(directory: str, tree_like, step: int | None = None, shardings=None):
    """Restore into the structure of ``tree_like`` (shapes must match).

    ``shardings``: optional pytree congruent with ``tree_like``; leaves are
    ``jax.sharding.Sharding`` used to place each array directly.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    folder = os.path.join(directory, f"step_{step:012d}")
    with open(os.path.join(folder, "manifest.json")) as f:
        manifest = json.load(f)
    flat_keys = list(_flatten(tree_like).keys())
    leaves_meta = manifest["leaves"]
    missing = [k for k in flat_keys if k not in leaves_meta]
    if missing:
        raise KeyError(f"checkpoint missing {len(missing)} leaves, e.g. {missing[:3]}")

    paths_and_leaves, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    sh_leaves = None
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_flatten(shardings)[0]
    out = []
    for i, (path, leaf) in enumerate(paths_and_leaves):
        key = _SAFE.sub("_", "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path))
        arr = np.load(os.path.join(folder, leaves_meta[key]["file"]))
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
        if sh_leaves is not None:
            out.append(jax.device_put(arr, sh_leaves[i]))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest


class AsyncCheckpointer:
    """One-in-flight background checkpoint writer."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, step: int, tree, extra: dict | None = None) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before async

        def _run():
            try:
                save_checkpoint(self.directory, step, host_tree, keep=self.keep, extra=extra)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
