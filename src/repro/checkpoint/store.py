"""Atomic, sharded, async-capable checkpointing (no orbax; from scratch).

Layout:  ``<dir>/step_<N>/{manifest.json, <leaf-id>.npy...}``
* leaves are path-addressed (stable across param-tree refactors that keep
  names), saved as host numpy;
* writes go to ``step_<N>.tmp`` then atomically ``rename`` — a crash mid-
  write never corrupts the latest checkpoint (the restart driver picks the
  newest *complete* step);
* ``AsyncCheckpointer`` overlaps serialization with the next train steps
  (one in-flight snapshot, joined before the next save — the standard
  double-buffer policy);
* ``restore`` optionally ``device_put``s straight into a sharding tree so
  a 512-way FSDP state never materialises unsharded on one host.

The same atomic-rename machinery also backs the *named-category* state
store used by :mod:`repro.persist` (``save_state`` / ``load_state``): a
manifest maps category names to per-category fingerprints, JSON metadata,
and ``.npy`` array leaves, so a schema like ``rknn-store/1`` can
invalidate one stale category without discarding the rest.

Completeness contract: a step only counts as restorable when its
manifest exists AND every leaf file the manifest lists is present —
stranded ``step_*.tmp`` leftovers (crash mid-write) and steps whose
leaves were lost (partial copy, interrupted gc) are skipped, never
tripped over.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading

import numpy as np

import jax

__all__ = [
    "save_checkpoint",
    "restore_checkpoint",
    "latest_step",
    "save_state",
    "load_state",
    "load_arrays",
    "AsyncCheckpointer",
]

_SAFE = re.compile(r"[^A-Za-z0-9_.\-]")


def _json_default(o):
    """Manifest metadata tolerates numpy scalars/arrays (PruneStats etc.)."""
    if isinstance(o, np.integer):
        return int(o)
    if isinstance(o, np.floating):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(f"Object of type {type(o).__name__} is not JSON serializable")


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out[_SAFE.sub("_", key)] = leaf
    return out


def _write_arrays(folder: str, arrays: dict, *, prefix: str = "") -> dict:
    """Save ``{key: array}`` as ``.npy`` leaves; returns manifest entries."""
    entries = {}
    for key, leaf in arrays.items():
        arr = np.asarray(leaf)
        fn = _SAFE.sub("_", f"{prefix}{key}".replace("/", "__")) + ".npy"
        np.save(os.path.join(folder, fn), arr)
        entries[key] = {
            "file": fn,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
    return entries


def _publish(directory: str, tmp: str, final: str, keep: int) -> str:
    """Atomic rename publish + retention gc (shared by both store kinds)."""
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    _gc(directory, keep)
    return final


def save_checkpoint(directory: str, step: int, tree, *, keep: int = 3, extra: dict | None = None) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:012d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"step": step, "leaves": _write_arrays(tmp, _flatten(tree)), "extra": extra or {}}
    # manifest last: its presence marks the leaves as fully written
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, default=_json_default)
    return _publish(directory, tmp, final, keep)


def _gc(directory: str, keep: int) -> None:
    steps = sorted(_all_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:012d}"), ignore_errors=True)


def _manifest_files(manifest: dict):
    """Every leaf filename a manifest references (param-tree ``leaves``
    and named-category ``categories`` layouts alike)."""
    for meta in manifest.get("leaves", {}).values():
        yield meta["file"]
    for cat in manifest.get("categories", {}).values():
        for meta in cat.get("arrays", {}).values():
            yield meta["file"]


def _step_complete(folder: str) -> bool:
    """Manifest present AND every leaf it lists exists on disk."""
    path = os.path.join(folder, "manifest.json")
    try:
        with open(path) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError):
        return False
    return all(
        os.path.exists(os.path.join(folder, fn)) for fn in _manifest_files(manifest)
    )


def _all_steps(directory: str) -> list[int]:
    out = []
    for name in os.listdir(directory):
        # fullmatch excludes stranded ``step_*.tmp`` crash leftovers
        m = re.fullmatch(r"step_(\d+)", name)
        if m and _step_complete(os.path.join(directory, name)):
            out.append(int(m.group(1)))
    return out


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = _all_steps(directory)
    return max(steps) if steps else None


def restore_checkpoint(directory: str, tree_like, step: int | None = None, shardings=None):
    """Restore into the structure of ``tree_like`` (shapes must match).

    ``shardings``: optional pytree congruent with ``tree_like``; leaves are
    ``jax.sharding.Sharding`` used to place each array directly.

    With ``step=None`` the newest *complete* step is used — incomplete
    ``.tmp`` leftovers and steps with missing leaf files are skipped.
    An explicitly requested step with a missing leaf raises a
    ``FileNotFoundError`` naming the leaf (not a bare ``np.load`` crash).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint under {directory}")
    folder = os.path.join(directory, f"step_{step:012d}")
    with open(os.path.join(folder, "manifest.json")) as f:
        manifest = json.load(f)
    flat_keys = list(_flatten(tree_like).keys())
    leaves_meta = manifest["leaves"]
    missing = [k for k in flat_keys if k not in leaves_meta]
    if missing:
        raise KeyError(f"checkpoint missing {len(missing)} leaves, e.g. {missing[:3]}")

    paths_and_leaves, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    sh_leaves = None
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_flatten(shardings)[0]
    out = []
    for i, (path, leaf) in enumerate(paths_and_leaves):
        key = _SAFE.sub("_", "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path))
        leaf_path = os.path.join(folder, leaves_meta[key]["file"])
        if not os.path.exists(leaf_path):
            raise FileNotFoundError(
                f"checkpoint step {step} lists leaf {key!r} but "
                f"{leaves_meta[key]['file']} is missing — the step is "
                f"incomplete (crash mid-write?); restore with step=None "
                f"to fall back to the newest complete step"
            )
        arr = np.load(leaf_path)
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
        if sh_leaves is not None:
            out.append(jax.device_put(arr, sh_leaves[i]))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest


# --------------------------------------------------------------------------
# named-category state store (the repro.persist substrate)
# --------------------------------------------------------------------------


def save_state(
    directory: str,
    step: int,
    categories: dict,
    *,
    schema: str,
    keep: int = 3,
    extra: dict | None = None,
) -> str:
    """Write named state categories atomically as one versioned step.

    ``categories`` maps a category name to ``{"fingerprint": str,
    "meta": dict, "arrays": {key: np.ndarray}}``.  The manifest carries
    the schema string and the per-category fingerprints so a reader can
    invalidate one stale category without touching the rest.
    """
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:012d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"schema": schema, "step": int(step), "categories": {}, "extra": extra or {}}
    for name, cat in categories.items():
        manifest["categories"][name] = {
            "fingerprint": str(cat.get("fingerprint", "")),
            "meta": cat.get("meta", {}),
            "arrays": _write_arrays(tmp, cat.get("arrays") or {}, prefix=f"{name}__"),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, default=_json_default)
    return _publish(directory, tmp, final, keep)


def load_state(
    directory: str, step: int | None = None, *, schema: str | None = None
) -> tuple[dict, str]:
    """Load the manifest of the newest complete step (arrays stay on disk
    — fetch per category with :func:`load_arrays`).  Returns
    ``(manifest, folder)``.  ``schema`` (when given) must match the
    stored schema string exactly — a future-major store is rejected
    rather than misread."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no complete state store under {directory}")
    folder = os.path.join(directory, f"step_{step:012d}")
    with open(os.path.join(folder, "manifest.json")) as f:
        manifest = json.load(f)
    if schema is not None and manifest.get("schema") != schema:
        raise ValueError(
            f"state store schema {manifest.get('schema')!r} does not match "
            f"expected {schema!r}"
        )
    return manifest, folder


def load_arrays(folder: str, entry: dict) -> dict:
    """Materialize one category's arrays from its manifest entry."""
    out = {}
    for key, meta in entry.get("arrays", {}).items():
        out[key] = np.load(os.path.join(folder, meta["file"]))
    return out


class AsyncCheckpointer:
    """One-in-flight background checkpoint writer."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, step: int, tree, extra: dict | None = None) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before async

        def _run():
            try:
                save_checkpoint(self.directory, step, host_tree, keep=self.keep, extra=extra)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
