"""Elastic re-meshing after node loss.

Policy: given the surviving device set, pick the largest mesh of shape
``(data', model)`` such that ``model`` keeps the TP degree if possible
(params re-shard cheaply along data) and the global batch still divides
``data'``.  State migrates through the checkpoint path-addressed format —
a restore into the new mesh's shardings is exactly the normal restart
flow, so elasticity re-uses the fault-tolerance machinery instead of a
bespoke resharding protocol (runtime/driver.py wires the two together).
"""

from __future__ import annotations

import dataclasses

import jax

__all__ = ["ElasticPlan", "plan_remesh", "build_remesh"]


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    data: int
    model: int
    n_used: int
    n_alive: int
    dropped_batch_rows: int  # if global batch had to shrink

    @property
    def shape(self) -> tuple[int, int]:
        return (self.data, self.model)


def plan_remesh(
    n_alive: int,
    *,
    prefer_model: int = 16,
    global_batch: int = 256,
    min_model: int = 1,
) -> ElasticPlan:
    """Largest usable (data, model) grid from ``n_alive`` devices."""
    # TP degree is a *memory-fit requirement* (params are model-sharded), so
    # keep it whenever possible and only halve when survivors can't fill a
    # single model group; the batch, not the device count, absorbs the
    # remainder (trimmed to a multiple of the data degree).
    model = prefer_model
    while model > min_model and n_alive < model:
        model //= 2
    data = max(n_alive // model, 1)
    batch_kept = (global_batch // data) * data if data <= global_batch else global_batch
    dropped = max(global_batch - batch_kept, 0)
    return ElasticPlan(data, model, data * model, n_alive, dropped)


def build_remesh(plan: ElasticPlan, devices=None):
    devices = devices if devices is not None else jax.devices()
    n = plan.data * plan.model
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    import numpy as np

    arr = np.asarray(devices[:n]).reshape(plan.data, plan.model)
    from jax.sharding import Mesh

    return Mesh(arr, ("data", "model"))
