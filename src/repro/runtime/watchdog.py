"""Straggler / hang detection for the training driver.

Two mechanisms sized for thousands-of-nodes operation:

* ``StepWatchdog`` — streaming mean/variance of step times (Welford); a
  step beyond ``mu + k*sigma`` (and an absolute floor) flags a straggler;
  repeated flags trigger the driver's mitigation callback (re-shard /
  restart — see runtime/driver.py).  Per-host, no coordination needed:
  with SPMD every host sees the same collective-bound step time, so the
  slowest participant is visible from anywhere.
* ``HangTimer`` — a hard wall-clock deadline per step (lost-node case,
  where the step never completes); fires a callback from a daemon thread.
"""

from __future__ import annotations

import threading
import time

__all__ = ["StepWatchdog", "HangTimer"]


class StepWatchdog:
    def __init__(self, k_sigma: float = 4.0, min_steps: int = 8, abs_floor_s: float = 0.05):
        self.k = k_sigma
        self.min_steps = min_steps
        self.abs_floor = abs_floor_s
        self.n = 0
        self.mean = 0.0
        self.m2 = 0.0
        self.flags = 0
        self._t0: float | None = None

    # -- streaming stats ---------------------------------------------------
    def _update(self, x: float) -> None:
        self.n += 1
        d = x - self.mean
        self.mean += d / self.n
        self.m2 += d * (x - self.mean)

    @property
    def sigma(self) -> float:
        return (self.m2 / max(self.n - 1, 1)) ** 0.5

    # -- step API ------------------------------------------------------------
    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self) -> bool:
        """Record the step; returns True if it was a straggler step."""
        assert self._t0 is not None, "stop() without start()"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        is_straggler = (
            self.n >= self.min_steps
            and dt > max(self.mean + self.k * self.sigma, self.abs_floor)
        )
        # stragglers don't poison the baseline statistics
        if not is_straggler:
            self._update(dt)
        else:
            self.flags += 1
        return is_straggler

    def observe(self, dt: float) -> bool:
        """Offline variant of start/stop for tests & simulations."""
        is_straggler = (
            self.n >= self.min_steps
            and dt > max(self.mean + self.k * self.sigma, self.abs_floor)
        )
        if not is_straggler:
            self._update(dt)
        else:
            self.flags += 1
        return is_straggler


class HangTimer:
    """Hard per-step deadline; calls ``on_hang`` from a daemon thread.

    ``flight`` (optional) is a :class:`repro.obs.FlightRecorder`: a hang
    dumps a postmortem bundle *before* the mitigation callback runs, so
    the spans/metrics of the wedged step survive whatever the mitigation
    does to the process.
    """

    def __init__(self, deadline_s: float, on_hang, *, flight=None):
        self.deadline = deadline_s
        self.on_hang = on_hang
        self.flight = flight
        self._timer: threading.Timer | None = None

    def _fire(self) -> None:
        if self.flight is not None:
            try:
                self.flight.dump("hang")
            except Exception:
                pass  # the black box must never mask the mitigation
        self.on_hang()

    def __enter__(self):
        self._timer = threading.Timer(self.deadline, self._fire)
        self._timer.daemon = True
        self._timer.start()
        return self

    def __exit__(self, *exc):
        if self._timer is not None:
            self._timer.cancel()
        return False
