"""Fault-tolerant training driver: checkpoint/restart + straggler + elastic.

The loop a real cluster job runs:

    while budget:
        state <- restore latest checkpoint (or init)
        try:   step, step, ... (watchdog timing, periodic async snapshots)
        except DeviceLoss: plan_remesh(survivors) -> restore into new mesh
        except transient:  retry with backoff, restart from last snapshot

Failure injection (``inject_failure``) lets the test suite exercise every
path on CPU: mid-run exceptions lose at most ``save_every - 1`` steps,
restarts are bit-deterministic (index-based data pipeline + checkpointed
optimizer state), and straggler flags feed the mitigation counter.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax

from repro.checkpoint.store import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.runtime.watchdog import StepWatchdog

__all__ = ["DriverConfig", "TrainDriver", "DeviceLoss"]


class DeviceLoss(RuntimeError):
    """Raised (or injected) when participating devices disappear."""

    def __init__(self, n_alive: int):
        super().__init__(f"device loss: {n_alive} alive")
        self.n_alive = n_alive


@dataclasses.dataclass
class DriverConfig:
    total_steps: int
    save_every: int = 50
    keep: int = 3
    max_retries: int = 3
    retry_backoff_s: float = 0.2
    straggler_k_sigma: float = 4.0


class TrainDriver:
    def __init__(
        self,
        ckpt_dir: str,
        cfg: DriverConfig,
        *,
        init_state: Callable[[], Any],
        step_fn: Callable[[Any, dict], tuple[Any, dict]],
        batch_fn: Callable[[int], dict],
        on_remesh: Callable[[int], None] | None = None,
        inject_failure: Callable[[int], None] | None = None,
    ):
        self.ckpt_dir = ckpt_dir
        self.cfg = cfg
        self.init_state = init_state
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.on_remesh = on_remesh
        self.inject_failure = inject_failure
        self.watchdog = StepWatchdog(k_sigma=cfg.straggler_k_sigma)
        self.ckpt = AsyncCheckpointer(ckpt_dir, keep=cfg.keep)
        self.events: list[str] = []
        self.metrics_log: list[dict] = []

    # ------------------------------------------------------------------
    def _restore_or_init(self):
        step = latest_step(self.ckpt_dir)
        state = self.init_state()
        if step is None:
            self.events.append("init:fresh")
            return state, 0
        state, manifest = restore_checkpoint(self.ckpt_dir, state)
        self.events.append(f"restore:step_{manifest['step']}")
        return state, int(manifest["step"])

    def run(self) -> tuple[Any, int]:
        retries = 0
        while True:
            state, start = self._restore_or_init()
            try:
                state, done = self._run_from(state, start)
                self.ckpt.wait()
                return state, done
            except DeviceLoss as e:
                self.events.append(f"device_loss:{e.n_alive}")
                self.ckpt.wait()
                if self.on_remesh is not None:
                    self.on_remesh(e.n_alive)
                    self.events.append("remesh")
                retries = 0  # re-meshed: reset transient budget
            except Exception as e:  # noqa: BLE001 — transient failure path
                retries += 1
                self.events.append(f"retry{retries}:{type(e).__name__}")
                if retries > self.cfg.max_retries:
                    raise
                self.ckpt.wait()
                time.sleep(self.cfg.retry_backoff_s * retries)

    def _run_from(self, state, start: int):
        for step in range(start, self.cfg.total_steps):
            if self.inject_failure is not None:
                self.inject_failure(step)
            batch = self.batch_fn(step)
            self.watchdog.start()
            state, metrics = self.step_fn(state, batch)
            jax.block_until_ready(jax.tree.leaves(metrics)[0] if jax.tree.leaves(metrics) else state)
            straggler = self.watchdog.stop()
            if straggler:
                self.events.append(f"straggler:step_{step}")
            self.metrics_log.append({"step": step, **{k: float(v) for k, v in metrics.items()}})
            done = step + 1
            if done % self.cfg.save_every == 0 or done == self.cfg.total_steps:
                self.ckpt.save(done, state)
                self.events.append(f"save:step_{done}")
        return state, self.cfg.total_steps
