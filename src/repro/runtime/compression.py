"""Int8 error-feedback gradient compression (1-bit-Adam-family trick).

At 1000+ nodes the cross-pod gradient all-reduce rides the slow DCI links;
quantizing grads to int8 with per-leaf scales cuts those bytes 4x (vs f32
accumulators).  Plain quantization biases training; **error feedback**
(Seide et al., Karimireddy et al.) fixes it: the residual ``g - Q(g)`` is
carried in optimizer-adjacent state and added back before the next
quantization, making the compression unbiased in the long run.

``make_compressor`` returns the hook consumed by
:func:`repro.steps.train.make_train_step` — compression happens *after*
microbatch accumulation and *before* the optimizer, i.e. exactly where the
cross-pod reduce would run; the quantize→dequantize round-trip in-graph
means the lowered HLO's gradient collectives carry int8-equivalent
information (the dry-run's all-reduce bytes drop accordingly when the
compressor is enabled with ``quantized_allreduce=True``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["make_compressor", "init_error_feedback", "quantize_int8", "dequantize_int8"]


def quantize_int8(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def make_compressor(*, quantized_allreduce: bool = True):
    """Hook: ``(grads, state) -> (grads', state')``.

    Expects ``state["ef"]`` (error-feedback buffers congruent with params);
    adds it lazily on first use.
    """

    def compress(grads, state):
        ef = state.get("ef")
        if ef is None:
            ef = init_error_feedback(grads)

        def one(g, e):
            g = g.astype(jnp.float32) + e
            q, scale = quantize_int8(g)
            deq = dequantize_int8(q, scale)
            return deq, g - deq

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_e = treedef.flatten_up_to(ef)
        outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        new_g = treedef.unflatten([o[0] for o in outs])
        new_e = treedef.unflatten([o[1] for o in outs])
        return new_g, dict(state, ef=new_e)

    return compress
