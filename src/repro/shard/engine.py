"""User-axis SPMD sharded serving: :class:`ShardedEngine`.

The paper's scaling axis is the *user* population — RT-RkNN casts one
ray per user, so users are where the parallel work lives, while
facilities (and the per-query occluder scenes built from them) are tiny.
The sharded engine encodes that asymmetry directly:

* **replicated** per shard: facilities, scenes, grid/BVH indexes, packed
  per-cell coefficient planes — all host-built once and shared;
* **sharded** over the ``'users'`` mesh axis: the user coordinate
  arrays, the per-shard cell buckets feeding the grid-pallas kernels,
  and the per-shard hit-count slabs.

The partition is *spatial*: users are sorted by grid cell (the same
cell id the bucketed kernels use) and cut into ``shards`` contiguous
runs (:func:`repro.distributed.sharding.user_shard_bounds`), so each
shard covers a compact region of the domain.  That is what makes
sharding a *throughput* lever even on one core: a shard only ships the
coefficient planes of cells **its** users occupy and only pads the
plane list axis to the longest live list in **its** region — strictly
less device work than the global dispatch, on top of whatever physical
parallelism the mesh provides.

Counts are per-user independent, so the per-shard slabs scatter back
through the partition permutation bit-identically to the single-process
oracle (:mod:`repro.shard.reduce`; property-tested across every
registered backend).  Per-query aggregates cross shards through the
``psum``-style tree reduction.

MVCC integration: the per-shard replicas live on the
:class:`~repro.core.snapshot.EngineSnapshot` (``snap.shard_state``) as
ONE immutable :class:`ShardState` swapped atomically — every view in a
state carries the snapshot's version, so a batch resolved against one
snapshot can never mix shard views from two versions (the
version-lockstep rule).  ``DynamicEngine`` user-move deltas scatter
functionally into the owning shard's device arrays along the same axis;
shape-changing deltas rebuild the partition lazily.
"""

from __future__ import annotations

import collections

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.backends import Backend, stack_cell_planes
from repro.core.engine import RkNNConfig
from repro.core.geometry import Rect
from repro.core.snapshot import EngineSnapshot, LruCache
from repro.distributed.sharding import user_shard_bounds
from repro.dynamic.engine import DynamicEngine
from repro.kernels import ops as _ops
from repro.obs import span
from repro.shard.mesh import mesh_shards, shard_devices
from repro.shard.reduce import tree_psum

__all__ = ["ShardedEngine", "ShardState", "ShardView", "ShardDispatch"]

#: Backend-name groups routed to each per-shard dispatch flavor.  The
#: grid-pallas family gets per-shard bucketing + compaction; the others
#: share one replicated prepared state and slice users per shard.
_GP_BACKENDS = frozenset({"grid-pallas", "grid-pallas-ref"})
_DENSE_BACKENDS = frozenset({"dense", "dense-ref"})
_GRID_BACKENDS = frozenset({"grid"})
_BVH_BACKENDS = frozenset({"bvh"})
_SHARDABLE = _GP_BACKENDS | _DENSE_BACKENDS | _GRID_BACKENDS | _BVH_BACKENDS


class ShardView:
    """One shard's replica view of one snapshot version.

    Owns the shard's user coordinates as device-resident ``f32`` arrays
    (pinned to ``device``) plus a private kernel memo for the per-shard
    cell bucketing — private so S shards cannot thrash the snapshot's
    small shared :class:`~repro.core.snapshot.LruCache`.
    """

    __slots__ = ("index", "device", "version", "lo", "hi", "xs", "ys", "memo")

    def __init__(self, index, device, version, lo, hi, xs, ys, memo=None):
        self.index = int(index)
        self.device = device
        self.version = int(version)
        self.lo = int(lo)
        self.hi = int(hi)
        self.xs = xs
        self.ys = ys
        self.memo = memo if memo is not None else LruCache(4)

    @property
    def n_users(self) -> int:
        return self.hi - self.lo


class ShardState:
    """The full shard partition of one snapshot version — swapped as ONE
    object (``snap.shard_state = state``), never mutated in place, so a
    reader resolves either all of version N's views or all of N+1's."""

    __slots__ = ("version", "n_shards", "perm", "pos", "bounds", "views", "n_users")

    def __init__(self, version, n_shards, perm, pos, bounds, views):
        self.version = int(version)
        self.n_shards = int(n_shards)
        self.perm = perm  # [N] spatial sort of user rows
        self.pos = pos  # [N] inverse: original row -> position in perm
        self.bounds = bounds  # [S+1] cut points into perm
        self.views = views  # tuple[ShardView], len S
        self.n_users = int(len(perm))

    def restamp(self, version: int) -> "ShardState":
        """The same partition re-stamped for a new snapshot version
        (facility-only deltas: user arrays carried by reference)."""
        views = tuple(
            ShardView(v.index, v.device, version, v.lo, v.hi, v.xs, v.ys, v.memo)
            for v in self.views
        )
        return ShardState(
            version, self.n_shards, self.perm, self.pos, self.bounds, views
        )

    def summary(self) -> dict:
        """JSON-able description of the partition for the introspection
        endpoint: per-shard row ranges, devices, and user counts (plus
        the imbalance ratio the sentinel watches).  Pure reads of
        immutable fields — safe against concurrent publication."""
        counts = [v.n_users for v in self.views]
        mean = (sum(counts) / len(counts)) if counts else 0.0
        return dict(
            version=self.version,
            n_shards=self.n_shards,
            n_users=self.n_users,
            imbalance=(max(counts) / mean) if mean else 1.0,
            shards=[
                dict(
                    index=v.index,
                    device=str(v.device),
                    lo=v.lo,
                    hi=v.hi,
                    n_users=v.n_users,
                )
                for v in self.views
            ],
        )


def _spatial_perm(users: np.ndarray, rect: Rect, grid_g: int) -> np.ndarray:
    """Stable sort of user rows by grid cell id — the same ``cx*G + cy``
    the bucketed kernels use, so each contiguous cut covers a compact
    cell range."""
    xs = users[:, 0].astype(np.float32)
    ys = users[:, 1].astype(np.float32)
    g = max(int(grid_g), 1)
    w = rect.width / g
    h = rect.height / g
    cx = np.clip(np.floor((xs - rect.xmin) / w), 0, g - 1).astype(np.int64)
    cy = np.clip(np.floor((ys - rect.ymin) / h), 0, g - 1).astype(np.int64)
    return np.argsort(cx * g + cy, kind="stable")


class ShardDispatch:
    """The per-batch sharded verify dispatch, injected as
    ``BatchRequest.dispatch``.

    The engine's filter phase calls :meth:`prepare` (via
    ``RkNNEngine._prepare_batch``) instead of the backend's own
    ``prepare_batch`` and the backend's ``count_batch`` calls the
    instance itself — so every batched path (fixed-backend batches,
    planner groups, ``stream()``) shards without knowing it.
    ``carries_users`` marks the per-shard prepared state as
    user-coordinate-bearing for the COW batch-cache carry.
    """

    carries_users = True

    def __init__(self, engine: "ShardedEngine", state: ShardState,
                 backend: Backend, rect: Rect, k: int):
        self.engine = engine
        self.state = state
        self.backend = backend
        self.rect = rect
        self.k = int(k)

    # ---- filter phase: per-shard (or replicated) prepared state --------
    def prepare(self, backend: Backend, req):
        name = backend.name
        state = self.state
        t_filter = [0.0] * state.n_shards
        if name in _GP_BACKENDS:
            indexes = req.indexes
            if indexes is None:
                indexes = [
                    backend.build_index(s, grid_g=req.grid_g) for s in req.scenes
                ]
            full_planes = [backend._planes_for(g) for g in indexes]
            per_shard = []
            for view in state.views:
                if view.n_users == 0:
                    per_shard.append(None)
                    continue
                with span("shard-filter", shard=view.index, backend=name) as sf:
                    xs_s, ys_s, order, ranks, occ, block = backend._buckets_for(
                        view.xs, view.ys, self.rect, req.grid_g, memo=view.memo
                    )
                    # the shard-local compaction double-whammy: only the cells
                    # THIS shard's users occupy ship, and the plane list axis
                    # pads to the longest live list in THIS region, not the
                    # global max
                    planes_q = stack_cell_planes(
                        [p[occ] for p in full_planes],
                        lane_pad=backend.lane_pad,
                        compact=True,
                    )
                    base_q = np.stack([g.base[occ] for g in indexes]).astype(np.int32)
                    xs_s = jax.device_put(xs_s, view.device)
                    ys_s = jax.device_put(ys_s, view.device)
                    # fuse the shard-local unsort with the global reassembly:
                    # kernel lane j's user sits at ``perm[lo + order[j]]`` in
                    # the original row order, so the dispatch can scatter the
                    # kernel output straight into the final array — one pass
                    # over [Q, N] instead of two.  Padding lanes route to the
                    # trash row ``n_users``.
                    ok = np.asarray(order) >= 0
                    dest = np.where(
                        ok,
                        state.perm[view.lo + np.clip(order, 0, None)],
                        state.n_users,
                    ).astype(np.int64)
                    per_shard.append(
                        (xs_s, ys_s, dest, ok, ranks, block, base_q, planes_q)
                    )
                t_filter[view.index] = sf.elapsed_s
            self.engine._note_shard_filter(t_filter)
            return ("shard", per_shard)
        # dense / grid / bvh: prepared state is a pure function of the
        # replicated scenes — build it once, slice users per shard at
        # dispatch time
        with span("shard-filter", shard=-1, backend=name, shared=1) as sf:
            shared = backend.prepare_batch(req)
        t_filter = [sf.elapsed_s / state.n_shards] * state.n_shards
        self.engine._note_shard_filter(t_filter)
        return ("shared", shared)

    # ---- verify phase: one dispatch per shard + fused reassembly -------
    def __call__(self, prepared) -> np.ndarray:
        kind, payload = prepared
        state = self.state
        backend = self.backend
        name = backend.name
        # The reassembly target is TRANSPOSED — ``[N + 1, Q]`` — so each
        # shard's scatter writes contiguous Q-wide rows at random offsets
        # (one cache line per user) instead of strided columns of a
        # ``[Q, N]`` array; at 10^6 users that is ~5x cheaper and it is
        # the only full-population pass the warm path makes.  Row ``N``
        # is the trash row the kernels' padding lanes land in.  The
        # returned ``[Q, N]`` transpose-view carries identical values to
        # :func:`repro.shard.reduce.assemble_counts` (the property-tested
        # reference composition).
        out_t: np.ndarray | None = None
        t_verify = [0.0] * state.n_shards
        partials: list[np.ndarray | None] = [None] * state.n_shards
        for i, view in enumerate(state.views):
            if view.n_users == 0:
                continue
            sv = span("shard-verify", shard=view.index, backend=name)
            sv.__enter__()
            if kind == "shard":
                xs_s, ys_s, dest, ok, ranks, block, base_q, planes_q = payload[i]
                counts = np.asarray(
                    _ops.grid_count_cells_batch(
                        xs_s, ys_s, ranks, base_q, planes_q,
                        block=block, backend=backend.kernel_backend,
                    )
                )
                if out_t is None:
                    out_t = np.zeros(
                        (state.n_users + 1, counts.shape[0]), np.int32
                    )
                out_t[dest] = counts.T
                part = ((counts < self.k) & ok).sum(axis=1).astype(np.int64)
            else:
                if name in _DENSE_BACKENDS:
                    slab = np.asarray(
                        _ops.raycast_count_batch(
                            view.xs, view.ys, payload,
                            backend=backend.kernel_backend,
                        )
                    )
                elif name in _GRID_BACKENDS:
                    from repro.core.grid import grid_hit_counts_batch_jnp

                    base, lists, coeffs = payload
                    slab = np.asarray(
                        grid_hit_counts_batch_jnp(
                            view.xs, view.ys, base, lists, coeffs,
                            self.rect, self.engine.config.grid_g,
                        )
                    )
                elif name in _BVH_BACKENDS:
                    from repro.core.bvh import bvh_hit_counts_batch

                    left, right, bbox, coeffs = payload
                    slab = np.asarray(
                        bvh_hit_counts_batch(
                            view.xs, view.ys, left, right, bbox, coeffs,
                            k=self.k,
                        )
                    )
                else:  # pragma: no cover — _mesh_dispatch_for gates the names
                    raise ValueError(f"unshardable backend {name!r}")
                if out_t is None:
                    out_t = np.zeros(
                        (state.n_users + 1, slab.shape[0]), np.int32
                    )
                out_t[state.perm[view.lo:view.hi]] = slab.T
                part = (slab < self.k).sum(axis=1).astype(np.int64)
            partials[view.index] = part
            sv.__exit__(None, None, None)
            t_verify[view.index] = sv.elapsed_s
        if out_t is None:  # pragma: no cover — n_users == 0 never dispatches
            return np.zeros((0, state.n_users), np.int32)
        n_q = out_t.shape[1]
        sizes = tree_psum(
            [p if p is not None else np.zeros(n_q, np.int64) for p in partials]
        )
        self.engine._note_shard_verify(
            t_verify,
            backend=name,
            version=state.version,
            per_shard_users=[v.n_users for v in state.views],
            sizes=sizes,
        )
        return out_t[: state.n_users].T


class ShardedEngine(DynamicEngine):
    """A :class:`~repro.dynamic.engine.DynamicEngine` whose verify phase
    is partitioned over a user-axis device mesh.

    Construction adds the mesh knobs; every query/update surface is
    inherited.  ``shards`` cycles the visible devices when the host has
    fewer (the partition and compaction are preserved; only physical
    parallelism collapses), or pass ``mesh=user_mesh(n)`` for a strict
    one-device-per-shard layout.  Masks and counts are bit-identical to
    the single-process engine for every concrete backend.
    """

    def __init__(
        self,
        facilities,
        users,
        config: RkNNConfig | None = None,
        *,
        shards: int | None = None,
        mesh=None,
        devices=None,
        rect: Rect | None = None,
        **overrides,
    ):
        if mesh is not None:
            n = mesh_shards(mesh)
            if shards is not None and int(shards) != n:
                raise ValueError(
                    f"shards={shards} disagrees with the mesh's users axis ({n})"
                )
            shards = n
            devices = shard_devices(n, mesh)
        if shards is None:
            shards = len(jax.devices())
        self.n_shards = max(int(shards), 1)
        self.shard_mesh = mesh
        self._shard_devices = (
            list(devices) if devices is not None else shard_devices(self.n_shards)
        )
        if len(self._shard_devices) != self.n_shards:
            raise ValueError(
                f"{self.n_shards} shards need {self.n_shards} devices, "
                f"got {len(self._shard_devices)}"
            )
        self._shard_log: "collections.deque[dict]" = collections.deque(maxlen=128)
        # base engine's `mesh=` kwarg is the training-style serve mesh —
        # deliberately NOT forwarded; the users mesh is this class's own
        super().__init__(facilities, users, config, rect=rect, **overrides)
        self.metrics.gauge("shard.imbalance").set(1.0)

    # ------------------------------------------------------------------
    # the shard partition (lazy per snapshot; one atomic install)
    # ------------------------------------------------------------------
    def _workload_shards(self) -> int:
        return self.n_shards

    def _shard_state_for(self, snap: EngineSnapshot) -> ShardState:
        st = snap.shard_state
        if (
            st is not None
            and st.version == snap.version
            and st.n_shards == self.n_shards
        ):
            return st
        users = snap.users
        n = len(users)
        perm = _spatial_perm(users, snap.rect, self.config.grid_g)
        pos = np.empty(n, np.int64)
        pos[perm] = np.arange(n)
        bounds = user_shard_bounds(n, self.n_shards)
        xs = users[:, 0].astype(np.float32)
        ys = users[:, 1].astype(np.float32)
        views = []
        for s in range(self.n_shards):
            lo, hi = int(bounds[s]), int(bounds[s + 1])
            sl = perm[lo:hi]
            dev = self._shard_devices[s]
            views.append(
                ShardView(
                    s, dev, snap.version, lo, hi,
                    jax.device_put(xs[sl], dev),
                    jax.device_put(ys[sl], dev),
                )
            )
        st = ShardState(snap.version, self.n_shards, perm, pos, bounds, tuple(views))
        # benign first-touch race: two racing builders produce equal
        # states; one atomic assignment wins (never a mixed-version set)
        snap.shard_state = st
        return st

    # ------------------------------------------------------------------
    # persistence hooks (repro.persist: the ``shards`` category)
    # ------------------------------------------------------------------
    def _persist_extra_fingerprints(self, snap: EngineSnapshot) -> dict:
        from repro.persist.store import _rect_parts, content_digest

        return {
            "shards": content_digest(
                "shards",
                snap.users,
                _rect_parts(snap.rect),
                int(self.config.grid_g),
                int(self.n_shards),
            )
        }

    def _persist_extra_categories(self, snap: EngineSnapshot) -> dict:
        st = self._shard_state_for(snap)
        return {
            "shards": {
                "meta": {"n_shards": int(st.n_shards)},
                "arrays": {"perm": st.perm, "pos": st.pos, "bounds": st.bounds},
            }
        }

    def _persist_adopt_extra(self, snap: EngineSnapshot, name: str, entry, arrays):
        if name != "shards":
            return None
        # the partition arrays come from the store; the per-shard device
        # views are re-placed locally (device topology is host state, not
        # store state)
        perm = np.ascontiguousarray(arrays["perm"], np.int64)
        pos = np.ascontiguousarray(arrays["pos"], np.int64)
        bounds = np.ascontiguousarray(arrays["bounds"], np.int64)
        users = snap.users
        xs = users[:, 0].astype(np.float32)
        ys = users[:, 1].astype(np.float32)
        views = []
        for s in range(self.n_shards):
            lo, hi = int(bounds[s]), int(bounds[s + 1])
            sl = perm[lo:hi]
            dev = self._shard_devices[s]
            views.append(
                ShardView(
                    s, dev, snap.version, lo, hi,
                    jax.device_put(xs[sl], dev),
                    jax.device_put(ys[sl], dev),
                )
            )
        snap.shard_state = ShardState(
            snap.version, self.n_shards, perm, pos, bounds, tuple(views)
        )
        return self.n_shards

    # ------------------------------------------------------------------
    # the dispatch injection point (covers batches, groups, stream)
    # ------------------------------------------------------------------
    def _mesh_dispatch_for(
        self, snap: EngineSnapshot, backend: Backend, *, rect: Rect, k: int
    ):
        if backend.name not in _SHARDABLE:
            return super()._mesh_dispatch_for(snap, backend, rect=rect, k=k)
        state = self._shard_state_for(snap)
        if state.n_users == 0:
            return None  # nothing to partition; single dispatch is exact
        return ShardDispatch(self, state, backend, rect, k)

    # ------------------------------------------------------------------
    # per-shard stats (metrics registry views; EngineStats + explain())
    # ------------------------------------------------------------------
    def _shard_hist(self, phase: str, i: int):
        key = ("shard", phase, i)
        h = self._metric_cache.get(key)
        if h is None:
            h = self._metric_cache[key] = self.metrics.histogram(
                "shard.phase_s", phase=phase, shard=i
            )
        return h

    def _note_shard_filter(self, times: list[float]) -> None:
        # every shard observes (zeros included) so the per-shard view
        # lists always span all n_shards entries
        for i, t in enumerate(times):
            self._shard_hist("filter", i).observe(t)

    def _note_shard_verify(
        self, times, *, backend, version, per_shard_users, sizes
    ) -> None:
        tot = [0.0] * self.n_shards
        for i, t in enumerate(times):
            self._shard_hist("verify", i).observe(t)
        for labels, h in self.metrics.find("shard.phase_s"):
            if labels.get("phase") == "verify":
                i = int(labels["shard"])
                if 0 <= i < self.n_shards:
                    tot[i] += h.sum
        mean = sum(tot) / max(len(tot), 1)
        imbalance = (max(tot) / mean) if mean > 0 else 1.0
        self.metrics.gauge("shard.imbalance").set(imbalance)
        self._shard_log.append(
            {
                "mode": "shard-batch",
                "backend": backend,
                "version": version,
                "shards": self.n_shards,
                "per_shard_users": list(per_shard_users),
                "per_shard_verify_s": [float(t) for t in times],
                "imbalance": imbalance,
                "result_sizes": [int(x) for x in np.asarray(sizes)],
            }
        )

    def explain(self) -> list[dict]:
        """Planner plans (inherited) followed by the per-batch shard
        records: per-shard user counts and verify timings, the running
        imbalance ratio, and the ``psum``-reduced result sizes."""
        return super().explain() + list(self._shard_log)

    # ------------------------------------------------------------------
    # COW update integration (scatter along the same axis)
    # ------------------------------------------------------------------
    def _cow_user_arrays(self, old, new, batch, report) -> None:
        super()._cow_user_arrays(old, new, batch, report)
        st = old.shard_state
        if st is None or st.n_shards != self.n_shards:
            return
        mv_ids, mv_pts = batch.user_move
        moves_only = (
            len(mv_ids) > 0
            and not len(batch.user_insert)
            and not len(batch.user_delete)
        )
        if not moves_only:
            return  # |U| changed: the partition itself is stale — rebuild lazily
        # functional scatter into the owning shards (old views untouched);
        # moved users keep their shard assignment until the next rebuild —
        # spatial purity degrades, correctness never does (any partition
        # is a valid partition)
        pos = st.pos[np.asarray(mv_ids, np.int64)]
        shard_of = np.searchsorted(st.bounds, pos, side="right") - 1
        views = []
        for s, view in enumerate(st.views):
            sel = shard_of == s
            if sel.any():
                local = jnp.asarray(pos[sel] - int(st.bounds[s]))
                xs = view.xs.at[local].set(
                    jnp.asarray(mv_pts[sel, 0], jnp.float32)
                )
                ys = view.ys.at[local].set(
                    jnp.asarray(mv_pts[sel, 1], jnp.float32)
                )
                views.append(
                    ShardView(s, view.device, new.version, view.lo, view.hi, xs, ys)
                )
            else:
                views.append(
                    ShardView(
                        s, view.device, new.version, view.lo, view.hi,
                        view.xs, view.ys, view.memo,
                    )
                )
        new.shard_state = ShardState(
            new.version, st.n_shards, st.perm, st.pos, st.bounds, tuple(views)
        )

    def _apply_updates_locked(self, batch):
        old = self._snap
        report = super()._apply_updates_locked(batch)
        new = self._snap
        st = old.shard_state
        if (
            new.shard_state is None
            and st is not None
            and st.n_shards == self.n_shards
            and not batch.touches_users
        ):
            # facility-only delta: the user partition is untouched — carry
            # every shard's device arrays by reference, re-stamped to the
            # new version in one atomic install (lockstep preserved)
            new.shard_state = st.restamp(new.version)
        return report
