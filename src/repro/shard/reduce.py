"""Cross-shard reductions for user-axis sharded serving.

Hit counts are **per-user independent** (one ray per user), so the
user-axis partition makes the count matrix itself embarrassingly
parallel: each shard produces the ``[Q, N_s]`` slab for the users it
owns and :func:`assemble_counts` scatters the slabs back through the
partition permutation — bit-identical to the single-process dispatch by
construction, no arithmetic crosses a shard boundary.

What *does* cross shards is every per-query aggregate — result-set
sizes, hit totals — which in a real SPMD deployment is a ``psum`` over
the ``'users'`` axis.  :func:`tree_psum` is that collective's host-side
twin: a butterfly/tree pairwise reduction whose combine order is fixed
by shard index, the same deterministic order ``jax.lax.psum`` uses, so
the aggregate a 4-shard mesh reports is reproducible and (for int
counts) exact.
"""

from __future__ import annotations

import numpy as np

__all__ = ["tree_psum", "assemble_counts", "result_sizes"]


def tree_psum(parts: list[np.ndarray]) -> np.ndarray:
    """Pairwise-tree sum of per-shard partials (the ``psum`` twin).

    Deterministic combine order: shards reduce with their power-of-two
    neighbor each round (0+1, 2+3, then 0+2, ...), exactly the butterfly
    a mesh collective runs, so results do not depend on Python iteration
    quirks and float partials reduce in a reproducible order.
    """
    if not parts:
        raise ValueError("tree_psum of zero shards")
    level = [np.asarray(p) for p in parts]
    while len(level) > 1:
        nxt = []
        for i in range(0, len(level) - 1, 2):
            nxt.append(level[i] + level[i + 1])
        if len(level) % 2:
            nxt.append(level[-1])
        level = nxt
    return level[0]


def assemble_counts(
    per_shard: list[np.ndarray],
    perm: np.ndarray,
    bounds: np.ndarray,
    n_users: int,
) -> np.ndarray:
    """``[Q, N]`` counts in original user order from per-shard slabs.

    ``per_shard[s]`` is ``[Q, bounds[s+1]-bounds[s]]`` in the order of
    ``perm[bounds[s]:bounds[s+1]]`` (the partition permutation).  Pure
    scatter — the per-user values are untouched, which is what makes the
    sharded masks bit-identical to the single-process oracle.

    This is the *reference* composition the property tests pin down; the
    hot dispatch (:meth:`repro.shard.engine.ShardDispatch.__call__`)
    fuses the same scatter with the kernels' bucket unsort into a single
    transposed pass, value-identical by construction.
    """
    q = per_shard[0].shape[0]
    out = np.zeros((q, int(n_users)), np.int32)
    for s, slab in enumerate(per_shard):
        lo, hi = int(bounds[s]), int(bounds[s + 1])
        out[:, perm[lo:hi]] = slab
    return out


def result_sizes(per_shard: list[np.ndarray], k: int) -> np.ndarray:
    """``[Q]`` RkNN result-set sizes via the cross-shard reduction: each
    shard contributes its local ``(counts < k).sum`` partial and the
    partials tree-reduce — the aggregate every shard of a real mesh
    would hold after the ``psum``."""
    partials = [
        (np.asarray(slab) < int(k)).sum(axis=1).astype(np.int64)
        for slab in per_shard
    ]
    return tree_psum(partials)
