"""Device meshes for user-axis sharded serving.

The sharded engine partitions the *user* population over a 1-D mesh whose
single axis is named ``'users'`` — deliberately distinct from the
training meshes' ``('pod', 'data', 'model')`` axes so the two kinds of
mesh can never be confused (``repro.distributed.meshctx.user_axes``
resolves logical ``'users'`` constraints against either).

On a development box the mesh is synthetic: launch with

    XLA_FLAGS=--xla_force_host_platform_device_count=4

and :func:`user_mesh` sees four ``CpuDevice``s.  Without the flag (or on
a box with fewer devices than shards) :func:`shard_devices` degrades
gracefully by cycling the available devices — the partition, the
per-shard compaction, and the bit-identical reduction are all preserved;
only the physical parallelism collapses onto the shared device.
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh

__all__ = ["user_mesh", "mesh_shards", "shard_devices"]


def user_mesh(n_shards: int | None = None, devices=None) -> Mesh:
    """A 1-D ``('users',)`` mesh over ``n_shards`` devices.

    ``n_shards=None`` uses every visible device.  Raises if fewer devices
    exist than shards requested — a jax ``Mesh`` cannot repeat a device;
    pass ``shards=`` to :class:`repro.shard.ShardedEngine` instead when
    oversubscribing a small host is the intent.
    """
    devs = list(devices if devices is not None else jax.devices())
    n = len(devs) if n_shards is None else int(n_shards)
    if n < 1:
        raise ValueError(f"need at least one shard, got {n}")
    if n > len(devs):
        raise ValueError(
            f"user_mesh: {n} shards requested but only {len(devs)} device(s) "
            "visible — launch with XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={n} for a synthetic CPU mesh, or pass shards="
            "to ShardedEngine to oversubscribe"
        )
    return Mesh(np.array(devs[:n]), axis_names=("users",))


def mesh_shards(mesh: Mesh) -> int:
    """Shard count of a serving mesh (the size of its ``'users'`` axis)."""
    if "users" not in mesh.axis_names:
        raise ValueError(
            f"expected a ('users',) serving mesh, got axes {mesh.axis_names}"
        )
    return int(mesh.shape["users"])


def shard_devices(n_shards: int, mesh: Mesh | None = None) -> list:
    """One device per shard.  From a mesh: its ``'users'`` axis devices.
    Without one: the visible devices, cycled when there are fewer devices
    than shards (single-device boxes still run every shard count)."""
    if mesh is not None:
        devs = list(mesh.devices.reshape(-1))
        if len(devs) != n_shards:
            raise ValueError(
                f"mesh has {len(devs)} devices but {n_shards} shards requested"
            )
        return devs
    devs = jax.devices()
    return [devs[i % len(devs)] for i in range(int(n_shards))]
