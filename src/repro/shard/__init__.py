"""User-axis SPMD sharded RkNN serving.

Quickstart (synthetic 4-device CPU mesh)::

    XLA_FLAGS=--xla_force_host_platform_device_count=4 python ...

    from repro.shard import ShardedEngine, user_mesh

    eng = ShardedEngine(facilities, users, mesh=user_mesh(4))
    masks = eng.query_batch(queries, k=10)   # bit-identical to RkNNEngine

See ``docs/API.md`` ("Sharded serving") for the replication-vs-sharding
contract and the version-lockstep rule.
"""

from repro.shard.engine import ShardDispatch, ShardedEngine, ShardState, ShardView
from repro.shard.mesh import mesh_shards, shard_devices, user_mesh
from repro.shard.reduce import assemble_counts, result_sizes, tree_psum

__all__ = [
    "ShardedEngine",
    "ShardDispatch",
    "ShardState",
    "ShardView",
    "user_mesh",
    "mesh_shards",
    "shard_devices",
    "tree_psum",
    "assemble_counts",
    "result_sizes",
]
