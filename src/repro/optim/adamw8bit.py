"""Block-wise 8-bit Adam moments (Dettmers et al., arXiv:2110.02861 style).

EXPERIMENTS §Dry-run identifies the llama3-405b single-pod blocker: fp32
Adam state is 12 B/param → 17.8 GiB/dev on 256 chips.  Quantizing both
moments to int8 with per-block (128-element) absmax scales cuts optimizer
state to 4 B/param + scales ≈ **params 4 B + moments 2.06 B = 6.1 GiB/dev**
— under the v5e budget without the second pod.

Implementation: moments are stored as ``{"q": int8, "scale": f32[blocks]}``
per leaf; each step dequantizes, applies the exact AdamW math from
:mod:`repro.optim.adamw`, and requantizes.  Signed linear quantization for
``m`` (zero-symmetric), and for ``v`` (non-negative) an unsigned scale.
The quantization error acts like bounded noise on the moments; the
standard result (and our convergence smoke test) is that training is
unaffected at lr scales used here.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamWConfig, clip_by_global_norm, make_schedule

__all__ = ["adamw8bit_init", "adamw8bit_update", "quantize_blockwise", "dequantize_blockwise"]

_BLOCK = 128


def _pad_len(n: int) -> int:
    return (-n) % _BLOCK


def quantize_blockwise(x, signed: bool = True):
    """x: any shape f32 -> (q int8, scale f32[nblocks], orig_shape)."""
    flat = x.reshape(-1)
    pad = _pad_len(flat.shape[0])
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    if signed:
        scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
        q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-30)[:, None]), -127, 127)
    else:
        scale = jnp.max(blocks, axis=1) / 255.0
        q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-30)[:, None]), 0, 255) - 128
    return q.astype(jnp.int8), scale


def dequantize_blockwise(q, scale, shape, signed: bool = True):
    blocks = q.astype(jnp.float32)
    if not signed:
        blocks = blocks + 128.0
    flat = (blocks * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def adamw8bit_init(params):
    def one(p):
        n = p.size
        nb = (n + _BLOCK - 1) // _BLOCK
        return {
            "mq": jnp.zeros((nb, _BLOCK), jnp.int8).reshape(nb, _BLOCK),
            "ms": jnp.zeros((nb,), jnp.float32),
            "vq": jnp.full((nb, _BLOCK), -128, jnp.int8),
            "vs": jnp.zeros((nb,), jnp.float32),
        }

    return {
        "m8": jax.tree.map(one, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw8bit_update(params, grads, state, cfg: AdamWConfig):
    """Same update law as :func:`adamw_update`, int8-backed moments."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = make_schedule(cfg)(step)
    b1, b2 = cfg.b1, cfg.b2

    def upd(p, g, s8):
        g = g.astype(jnp.float32)
        m = dequantize_blockwise(s8["mq"], s8["ms"], p.shape, signed=True)
        v = dequantize_blockwise(s8["vq"], s8["vs"], p.shape, signed=False)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** step.astype(jnp.float32))
        vh = v / (1 - b2 ** step.astype(jnp.float32))
        pf = p.astype(jnp.float32)
        pn = pf - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * pf)
        mq, ms = quantize_blockwise(m, signed=True)
        vq, vs = quantize_blockwise(v, signed=False)
        return pn.astype(p.dtype), {"mq": mq, "ms": ms, "vq": vq, "vs": vs}

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(state["m8"])
    outs = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_p = treedef.unflatten([o[0] for o in outs])
    new_s = treedef.unflatten([o[1] for o in outs])
    return new_p, {"m8": new_s, "step": step}, {"grad_norm": gnorm, "lr": lr}
