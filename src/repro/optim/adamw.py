"""AdamW + gradient clipping + LR schedules, from scratch (no optax).

Optimizer state is a pytree congruent with params (``m``/``v`` per leaf),
so the sharding rules that shard a parameter shard its moments identically
— with FSDP-sharded params this is ZeRO-style optimizer-state sharding for
free.  All moment math runs in fp32 regardless of compute dtype.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm", "clip_by_global_norm", "make_schedule"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"  # cosine | linear | constant


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def make_schedule(cfg: AdamWConfig) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
        if cfg.schedule == "constant":
            decay = 1.0
        elif cfg.schedule == "linear":
            frac = jnp.clip(
                (step - cfg.warmup_steps)
                / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                0.0,
                1.0,
            )
            decay = 1.0 - frac
        else:  # cosine
            frac = jnp.clip(
                (step - cfg.warmup_steps)
                / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                0.0,
                1.0,
            )
            decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return cfg.lr * warm * decay

    return sched


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = make_schedule(cfg)(step)
    b1, b2 = cfg.b1, cfg.b2

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** step.astype(jnp.float32))
        vh = v / (1 - b2 ** step.astype(jnp.float32))
        pf = p.astype(jnp.float32)
        pn = pf - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * pf)
        return pn.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return (
        new_p,
        {"m": new_m, "v": new_v, "step": step},
        {"grad_norm": gnorm, "lr": lr},
    )
