"""Spatial dataset generators for the RkNN benchmarks.

The paper evaluates on six DIMACS road networks (NY ... USA, Fig. 6) —
offline here, so we generate *road-network-like* point sets: a random
planar polyline graph whose edges are densely sampled with jitter, which
reproduces the clustered-linear structure of road vertices, plus uniform
and Gaussian-cluster alternatives for ablations.  Deterministic by seed;
paper cardinalities are reproduced (scaled by ``--scale`` in benchmarks).
"""

from __future__ import annotations

import numpy as np

__all__ = ["road_network_points", "uniform_points", "clustered_points", "PAPER_DATASETS"]

# paper Table 1 cardinalities
PAPER_DATASETS = {
    "NY": 264_346,
    "FLA": 1_070_376,
    "CAL": 1_890_815,
    "E": 3_598_623,
    "CTR": 14_081_816,
    "USA": 23_947_347,
}


def road_network_points(n: int, seed: int = 0, n_hubs: int | None = None) -> np.ndarray:
    """~n points along the edges of a random planar hub graph."""
    rng = np.random.default_rng(seed)
    n_hubs = n_hubs or max(16, int(np.sqrt(n) / 4))
    hubs = rng.random((n_hubs, 2))
    # connect each hub to its 3 nearest -> polyline "roads"
    d2 = np.sum((hubs[:, None] - hubs[None, :]) ** 2, axis=-1)
    np.fill_diagonal(d2, np.inf)
    edges = []
    for i in range(n_hubs):
        for j in np.argsort(d2[i])[:3]:
            if i < j:
                edges.append((i, int(j)))
    edges = np.asarray(edges)
    lengths = np.linalg.norm(hubs[edges[:, 0]] - hubs[edges[:, 1]], axis=1)
    probs = lengths / lengths.sum()
    counts = rng.multinomial(n, probs)
    pts = []
    for (a, b), c in zip(edges, counts):
        if c == 0:
            continue
        t = rng.random(c)[:, None]
        p = hubs[a][None] * (1 - t) + hubs[b][None] * t
        p = p + rng.normal(0.0, 0.002, p.shape)  # GPS-ish jitter
        pts.append(p)
    out = np.concatenate(pts) if pts else np.zeros((0, 2))
    if len(out) < n:  # multinomial rounding
        out = np.concatenate([out, rng.random((n - len(out), 2))])
    return np.clip(out[:n], 0.0, 1.0)


def uniform_points(n: int, seed: int = 0) -> np.ndarray:
    return np.random.default_rng(seed).random((n, 2))


def clustered_points(n: int, seed: int = 0, n_clusters: int = 32, spread: float = 0.02) -> np.ndarray:
    rng = np.random.default_rng(seed)
    centers = rng.random((n_clusters, 2))
    assign = rng.integers(0, n_clusters, n)
    pts = centers[assign] + rng.normal(0, spread, (n, 2))
    return np.clip(pts, 0.0, 1.0)


def facility_user_split(points: np.ndarray, n_facilities: int, seed: int = 0):
    """Paper protocol: |F| random points are facilities, the rest users."""
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(points))
    f = points[idx[:n_facilities]]
    u = points[idx[n_facilities:]]
    return f, u
