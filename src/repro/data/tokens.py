"""Deterministic sharded token pipeline.

Index-based (stateless) loading: batch ``i`` of host ``h`` is a pure
function of ``(seed, step, host, n_hosts)`` — so resuming from a
checkpointed step reproduces the exact stream with no iterator state to
snapshot, and host shards are disjoint by construction.  The synthetic
distribution is Zipf-ish over the vocab with a short-range Markov flavor so
the loss actually decreases during the example runs (unlike uniform noise).
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["TokenPipelineConfig", "ShardedTokenPipeline"]


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3


class ShardedTokenPipeline:
    """Yields ``{"tokens", "labels"}`` batches for one host's shard."""

    def __init__(self, cfg: TokenPipelineConfig, host: int = 0, n_hosts: int = 1):
        if cfg.global_batch % n_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.cfg = cfg
        self.host = host
        self.n_hosts = n_hosts
        self.local_batch = cfg.global_batch // n_hosts
        # Zipf-ish stationary distribution (clipped + renormalised)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._p = p / p.sum()

    def _row_rng(self, step: int, row: int) -> np.random.Generator:
        # disjoint by construction: global row id folds host shard and step
        gid = (step * self.cfg.global_batch) + self.host * self.local_batch + row
        return np.random.default_rng(np.random.SeedSequence([self.cfg.seed, gid]))

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        S = self.cfg.seq_len
        tokens = np.empty((self.local_batch, S + 1), dtype=np.int32)
        for r in range(self.local_batch):
            rng = self._row_rng(step, r)
            base = rng.choice(self.cfg.vocab, size=S + 1, p=self._p)
            # short-range structure: with p=0.5 repeat of t-1 offset by 1
            rep = rng.random(S) < 0.5
            base[1:][rep] = (base[:-1][rep] + 1) % self.cfg.vocab
            tokens[r] = base
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
